//! The distributed trainer: N in-process "GPU nodes", each computing
//! gradients through its own PJRT engine (L2/L1 HLO), exchanging them
//! through the compressed collectives, and updating its Zero-2 parameter
//! shard.
//!
//! Data flow per optimizer step on node `n` (Sec. 3 of the paper):
//!
//! 1. `accum` fused fwd+bwd executions on local microbatches (L2 graph);
//! 2. local gradient average, optional element-wise clip (Sec. 5.2);
//! 3. **compress** each destination shard with the configured method
//!    (LoCo: Algorithm 1 steps 1–2);
//! 4. **all-to-all** exchange of low-bit shards (Sec. 3.3 — avoids the
//!    repeated quantize/dequantize of ring reduce-scatter);
//! 5. decode + fp32 average of the N received shards (Eqn. 8),
//!    optional global-norm clip (scalar tree all-reduce);
//! 6. optimizer step on the fp32 *master* copy of the own shard;
//! 7. parameter all-gather at `param_sync` precision (bf16 by default,
//!    matching the paper's b_w = 16).
//!
//! With `sync_params = "async"` step 7 is split: the gather is *launched*
//! after the optimizer step (non-blocking tagged sends), the next step's
//! forward/backward runs against a double-buffered one-step-stale
//! parameter view, and the handle is drained only before the next
//! optimizer step — hiding the gather behind compute (0/1 Adam-style
//! bounded staleness; DESIGN.md §"Async parameter sync").
//!
//! `grad_sync` generalizes the same launch → compute → drain lifecycle
//! to steps 3–5 (DESIGN.md §"Gradient staleness"):
//! * `"stale"` launches the compressed all-to-all right after step k's
//!   backward and drains it at step k+1, applying the one-step-stale
//!   averaged gradient (error feedback intact) — the 0/1 Adam schedule.
//!   The final step's exchange drains after the loop, so every gradient
//!   is applied exactly once.
//! * `"local:H"` runs H local SGD steps between exchanges and ships the
//!   round's accumulated *pseudo-gradient* (the parameter delta,
//!   normalized by the summed inner learning rates) through the same
//!   LoCo compressors — H× fewer exchanges on the wire (DiLoCo /
//!   SparseLoCo lineage).
//! `"sync"` (the default) is bitwise identical to the pre-stale trainer.
//!
//! DDP mode (Table 6 / PowerSGD) replaces 3–5 with a full-gradient
//! all-reduce (tree, or the PowerSGD two-phase protocol) and keeps full
//! optimizer state on every node.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::ckpt::{Checkpoint, RankState};
use crate::collective::{run_cluster_topo, FaultSchedule, NodeCtx};
use crate::compress::{
    self, powersgd::PowerSgd, CompressorConfig, Decoder, Encoder, Method,
};
use crate::data::{Corpus, CorpusConfig, Split};
use crate::metrics::RunMetrics;
use crate::model::ModelMeta;
use crate::optim::{self, LrSchedule, OptimConfig};
use crate::runtime::Engine;
use crate::sharding::Partition;
use crate::topology::{HierSyncEngine, PendingHierGrads, PendingHierParams, Topology};
use crate::util;

/// Gradient synchronization topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Zero-2 sharded: compressed all-to-all + param all-gather (default).
    Zero2,
    /// Zero-2 with fp32 ring reduce-scatter (reference path; ignores the
    /// compressor for gradients).
    Zero2ReduceScatter,
    /// Data-parallel with full-gradient tree all-reduce; PowerSGD runs its
    /// two-phase protocol here.
    Ddp,
}

/// Parameter all-gather precision (paper: 16-bit weights on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamSync {
    /// Full-precision parameters on the wire (reference).
    F32,
    /// bf16 parameters on the wire (the paper's b_w = 16 default).
    Bf16,
}

/// When the gathered parameters become visible to the forward pass
/// (`train.sync_params`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncParams {
    /// Gather before the next forward — the paper's schedule, bitwise
    /// identical to the pre-async trainer (default).
    Sync,
    /// One-step-stale: launch the gather right after the optimizer step,
    /// run the next forward/backward against the previous parameter
    /// view, and drain the gather only before the next optimizer step —
    /// the wire carries the parameters while compute runs
    /// (DESIGN.md §"Async parameter sync").
    Async,
}

/// When the gradient exchange runs relative to the optimizer update
/// (`train.grad_sync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradSync {
    /// Exchange and apply in the same step — the paper's schedule,
    /// bitwise identical to the pre-stale trainer (default).
    Sync,
    /// Launch the compressed all-to-all after step k's backward, drain it
    /// during step k+1 and apply the one-step-stale averaged gradient —
    /// the exchange rides the wire while the next forward/backward runs
    /// (0/1 Adam lineage; DESIGN.md §"Gradient staleness").
    Stale,
    /// Run H local SGD steps between exchanges and synchronize the
    /// round's accumulated pseudo-gradient (parameter delta, normalized
    /// by the summed inner learning rates) through the configured
    /// compressors — H× fewer exchanges (DiLoCo / SparseLoCo lineage).
    Local(u64),
}

impl GradSync {
    /// Parse `"sync" | "stale" | "local:H"` (H ≥ 1).
    pub fn parse(s: &str) -> Option<GradSync> {
        match s {
            "sync" => Some(GradSync::Sync),
            "stale" => Some(GradSync::Stale),
            _ => s
                .strip_prefix("local:")
                .and_then(|h| h.parse().ok())
                .filter(|&h| h >= 1)
                .map(GradSync::Local),
        }
    }
}

/// How the trainer reacts when the replayed fault schedule
/// ([`FaultSchedule`]) says a drain barrier would block on a straggler
/// (`train.fault_policy`). Rank death is handled the same way under every
/// policy: the dead rank contributes a zero gradient (its error-feedback
/// residual is re-zeroed at death onset, except for EF21), stays in every
/// collective, and resumes computing on rejoin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Wait the straggler out (default): numerics are bitwise the
    /// fault-free run; the modeled wait is charged to
    /// [`crate::metrics::RunMetrics::fault_wait_s`].
    Wait,
    /// Time the straggler out: it skips its forward/backward and ships a
    /// zero gradient (its error-feedback residual still rides the
    /// exchange — only the fresh gradient is dropped), and every rank
    /// divides by the contributor count. Works in every sync mode and on
    /// every topology.
    Skip,
    /// Reuse the one-step-stale view another step instead of draining:
    /// the in-flight exchange stays on the wire, this step's fresh
    /// gradients are dropped, and after `faults.max_defer` consecutive
    /// deferrals the drain happens anyway. Requires
    /// `train.grad_sync = stale`.
    Defer,
}

impl FaultPolicy {
    /// Parse `"wait" | "skip" | "defer"`.
    pub fn parse(s: &str) -> Option<FaultPolicy> {
        match s {
            "wait" => Some(FaultPolicy::Wait),
            "skip" => Some(FaultPolicy::Skip),
            "defer" => Some(FaultPolicy::Defer),
            _ => None,
        }
    }

    /// The config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            FaultPolicy::Wait => "wait",
            FaultPolicy::Skip => "skip",
            FaultPolicy::Defer => "defer",
        }
    }
}

/// Everything one training run needs.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// model config name (must have artifacts: `model_<name>_*.hlo.txt`)
    pub model: String,
    pub art_dir: PathBuf,
    pub nodes: usize,
    pub steps: u64,
    pub accum: usize,
    pub seed: u64,
    pub mode: Mode,
    pub param_sync: ParamSync,
    /// synchronous vs one-step-stale asynchronous parameter gather
    /// (Zero-2 modes only; `Sync` is bitwise the pre-async trainer)
    pub sync_params: SyncParams,
    /// when the gradient exchange runs: per-step (`Sync`, bitwise the
    /// pre-stale trainer), one step stale (`Stale`), or every H local
    /// steps (`Local(H)`) — Zero-2 mode only for the non-default values
    pub grad_sync: GradSync,
    pub optim: OptimConfig,
    pub lr: LrSchedule,
    pub compressor: CompressorConfig,
    /// number of NVLink islands for the two-level topology (Zero-2 only);
    /// 0/1 = flat cluster, the pre-topology engine bit-for-bit. The
    /// legacy spelling of `tiers = [nodes/islands, islands]`.
    pub islands: usize,
    /// recursive tier tree, innermost (leaf island size) first —
    /// `[4, 2, 2]` = 2 racks of 2 islands of 4 nodes (`topology.tiers`;
    /// Zero-2 only). Empty = use `islands`. `[n]` degrades bitwise to
    /// the flat engine, `[m, k]` to the two-level one.
    pub tiers: Vec<usize>,
    /// explicit uneven leaf islands (`topology.groups`, e.g.
    /// `[[0,1,2],[3,4,5,6,7]]`; Zero-2 only, excludes `tiers`/`islands`)
    pub topo_groups: Vec<Vec<usize>>,
    /// global-norm clip on the averaged gradient (0 = off)
    pub global_clip: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub log_every: u64,
    /// start from these parameters instead of fresh init (fine-tuning)
    pub init_params: Option<Vec<f32>>,
    /// corpus noise level (distribution shift for fine-tuning experiments)
    pub corpus_noise: Option<f64>,
    pub corpus_seed: u64,
    /// seeded fault schedule replayed deterministically at step
    /// boundaries (`faults.events` / `faults.seed`; empty = fault-free).
    /// Zero-2 mode only.
    pub faults: FaultSchedule,
    /// straggler handling at drain barriers (`train.fault_policy`)
    pub fault_policy: FaultPolicy,
    /// modeled drain-barrier budget in milliseconds: the unit of the
    /// per-straggler wait charged under `wait`, and the timeout that
    /// `skip`/`defer` treat as exceeded (`faults.drain_timeout_ms`)
    pub drain_timeout_ms: u64,
    /// maximum consecutive `defer` deferrals before draining anyway
    /// (`faults.max_defer`)
    pub max_defer: u64,
    /// write a [`Checkpoint`] here when `save_at` is reached
    /// (`checkpoint.save_path`)
    pub save_path: Option<PathBuf>,
    /// step boundary to checkpoint at — the checkpoint is taken after
    /// step `save_at - 1` completes; 0 = never (`checkpoint.save_at`)
    pub save_at: u64,
    /// resume from this checkpoint instead of a fresh init
    /// (`checkpoint.resume_from`)
    pub resume_from: Option<PathBuf>,
    /// write a Chrome-trace/Perfetto JSON of the run here (`trace.path`
    /// / `loco train --trace`); `None` = tracing off, zero overhead on
    /// the hot path. Traces are keyed to each rank's deterministic
    /// simulated clock, so identically-seeded runs emit byte-identical
    /// files (DESIGN.md §3.11).
    pub trace_path: Option<PathBuf>,
    /// per-rank trace ring-buffer capacity in events (`trace.buffer`);
    /// the oldest events are dropped — and counted — once it fills
    pub trace_buf: usize,
}

impl TrainConfig {
    pub fn new(model: &str) -> Self {
        TrainConfig {
            model: model.to_string(),
            art_dir: crate::runtime::artifacts_dir(),
            nodes: 4,
            steps: 100,
            accum: 1,
            seed: 0,
            mode: Mode::Zero2,
            param_sync: ParamSync::Bf16,
            sync_params: SyncParams::Sync,
            grad_sync: GradSync::Sync,
            optim: OptimConfig::default(),
            lr: LrSchedule::constant(1e-3),
            compressor: CompressorConfig::default(),
            islands: 1,
            tiers: Vec::new(),
            topo_groups: Vec::new(),
            global_clip: 1.0,
            eval_every: 0,
            eval_batches: 4,
            log_every: 10,
            init_params: None,
            corpus_noise: None,
            corpus_seed: 1234,
            faults: FaultSchedule::empty(),
            fault_policy: FaultPolicy::Wait,
            drain_timeout_ms: 100,
            max_defer: 3,
            save_path: None,
            save_at: 0,
            resume_from: None,
            trace_path: None,
            trace_buf: 1 << 20,
        }
    }
}

/// Result of a run: metrics plus the final full parameter vector.
pub struct RunResult {
    pub metrics: RunMetrics,
    pub final_params: Vec<f32>,
}

/// The multi-node trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg }
    }

    /// Run the configured training job; returns rank-0's metrics and the
    /// final parameters.
    pub fn run(&self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let meta = crate::runtime::load_meta(&cfg.art_dir, &cfg.model)?;
        let n = cfg.nodes;
        let topo = if !cfg.topo_groups.is_empty() {
            anyhow::ensure!(
                cfg.tiers.is_empty() && cfg.islands <= 1,
                "topology.groups excludes topology.tiers and topology.islands"
            );
            Topology::from_groups(n, cfg.topo_groups.clone())?
        } else if !cfg.tiers.is_empty() {
            anyhow::ensure!(
                cfg.islands <= 1,
                "set topology.tiers or topology.islands, not both"
            );
            Topology::from_tiers(n, &cfg.tiers)?
        } else {
            Topology::new(n, cfg.islands.max(1))?
        };
        anyhow::ensure!(
            !topo.is_hierarchical() || cfg.mode == Mode::Zero2,
            "hierarchical topologies (islands / tiers / groups) require train.mode = zero2"
        );
        anyhow::ensure!(
            cfg.sync_params == SyncParams::Sync || cfg.mode != Mode::Ddp,
            "train.sync_params = async requires a Zero-2 mode (DDP has no parameter gather)"
        );
        anyhow::ensure!(
            cfg.grad_sync == GradSync::Sync || cfg.mode == Mode::Zero2,
            "train.grad_sync = stale | local:H requires train.mode = zero2 \
             (the exchange goes through the compressed sync engine)"
        );
        if let GradSync::Local(h) = cfg.grad_sync {
            anyhow::ensure!(h >= 1, "train.grad_sync = local:H needs H >= 1");
            anyhow::ensure!(
                cfg.sync_params == SyncParams::Sync,
                "train.grad_sync = local:H requires train.sync_params = sync \
                 (the round-end gather must complete before the next round's local steps)"
            );
        }
        if !cfg.faults.is_empty() {
            anyhow::ensure!(
                cfg.mode == Mode::Zero2,
                "fault injection (faults.events) requires train.mode = zero2"
            );
            for e in &cfg.faults.events {
                anyhow::ensure!(
                    e.rank < n,
                    "fault event targets rank {} of a {n}-node cluster",
                    e.rank
                );
            }
        }
        anyhow::ensure!(
            cfg.fault_policy != FaultPolicy::Defer || cfg.grad_sync == GradSync::Stale,
            "train.fault_policy = defer reuses the in-flight stale exchange; \
             it requires train.grad_sync = stale"
        );
        if cfg.save_at > 0 || cfg.resume_from.is_some() {
            anyhow::ensure!(
                cfg.mode == Mode::Zero2,
                "checkpointing (checkpoint.save_at / checkpoint.resume_from) \
                 requires train.mode = zero2"
            );
            anyhow::ensure!(
                cfg.compressor.method != Method::PowerSgd,
                "PowerSGD holds unserialized low-rank state; it cannot checkpoint"
            );
        }
        if cfg.save_at > 0 {
            anyhow::ensure!(
                cfg.save_path.is_some(),
                "checkpoint.save_at needs checkpoint.save_path"
            );
            anyhow::ensure!(
                cfg.save_at <= cfg.steps,
                "checkpoint.save_at {} is past train.steps {}",
                cfg.save_at,
                cfg.steps
            );
            if let GradSync::Local(h) = cfg.grad_sync {
                anyhow::ensure!(
                    cfg.save_at % h == 0,
                    "checkpoint.save_at {} must land on a local:{h} round boundary",
                    cfg.save_at
                );
            }
        }
        let resume = match &cfg.resume_from {
            Some(path) => {
                let ck = Checkpoint::load(path)?;
                anyhow::ensure!(
                    ck.n == n && ck.total == meta.layout.total,
                    "checkpoint was taken on {} ranks / {} params; this run has {n} / {}",
                    ck.n,
                    ck.total,
                    meta.layout.total
                );
                anyhow::ensure!(
                    ck.seed == cfg.seed && ck.corpus_seed == cfg.corpus_seed,
                    "checkpoint seeds ({}, {}) do not match the run's ({}, {})",
                    ck.seed,
                    ck.corpus_seed,
                    cfg.seed,
                    cfg.corpus_seed
                );
                anyhow::ensure!(
                    ck.step < cfg.steps,
                    "checkpoint at step {} has nothing left to run (train.steps = {})",
                    ck.step,
                    cfg.steps
                );
                if let GradSync::Local(h) = cfg.grad_sync {
                    anyhow::ensure!(
                        ck.step % h == 0,
                        "checkpoint step {} is not a local:{h} round boundary",
                        ck.step
                    );
                }
                Some(ck)
            }
            None => None,
        };
        let part = match cfg.mode {
            Mode::Ddp => Partition { ranges: vec![0..meta.layout.total] },
            Mode::Zero2 if topo.is_hierarchical() => topo.partition(meta.layout.total),
            _ => Partition::tensor_aligned(&meta.layout, n),
        };
        let result0: Mutex<Option<RunResult>> = Mutex::new(None);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

        // flat clusters keep the run_cluster convention (every byte is
        // "inter-island": there is no fast level to hide traffic on);
        // hierarchical ones count bytes per tier level
        let mut spec = topo.cluster_spec();
        spec.faults = (!cfg.faults.is_empty()).then(|| Arc::new(cfg.faults.clone()));
        // each rank parks its frozen state here at the save barrier;
        // rank 0 assembles the checkpoint once every slot is filled
        let save_slots: Mutex<Vec<Option<RankState>>> =
            Mutex::new((0..n).map(|_| None).collect());
        // each rank parks its finished trace here; rank order in the
        // output file is fixed so identically-seeded runs emit identical
        // bytes regardless of thread scheduling
        let trace_slots: Mutex<Vec<Option<crate::trace::RankTrace>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let (_, counters) = run_cluster_topo(n, spec, |ctx| {
            match self.node_main(&ctx, &meta, &part, &topo, resume.as_ref(), &save_slots, &trace_slots)
            {
                Ok(Some(r)) => {
                    *result0.lock().unwrap() = Some(r);
                }
                Ok(None) => {}
                Err(e) => {
                    errors.lock().unwrap().push(format!("node {}: {e:#}", ctx.rank));
                }
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            anyhow::bail!("training failed: {}", errs.join("; "));
        }
        if let Some(path) = &cfg.trace_path {
            let traces: Vec<crate::trace::RankTrace> = trace_slots
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|t| t.context("a rank finished without parking its trace"))
                .collect::<Result<_>>()?;
            crate::trace::write_chrome_trace(path, &traces)
                .with_context(|| format!("writing trace to {}", path.display()))?;
        }
        let mut result = result0
            .into_inner()
            .unwrap()
            .context("rank 0 produced no result")?;
        result.metrics.comm_bytes = counters.total_sent();
        result.metrics.comm_bytes_intra = counters.total_intra();
        result.metrics.comm_bytes_inter = counters.total_inter();
        Ok(result)
    }

    #[allow(clippy::too_many_arguments)]
    fn node_main(
        &self,
        ctx: &NodeCtx,
        meta: &ModelMeta,
        part: &Partition,
        topo: &Topology,
        resume: Option<&Checkpoint>,
        save_slots: &Mutex<Vec<Option<RankState>>>,
        trace_slots: &Mutex<Vec<Option<crate::trace::RankTrace>>>,
    ) -> Result<Option<RunResult>> {
        let cfg = &self.cfg;
        let rank = ctx.rank;
        let n = ctx.n;
        let total = meta.layout.total;
        let my_range = if cfg.mode == Mode::Ddp { 0..total } else { part.ranges[rank].clone() };
        let t0 = util::timer::Stopwatch::start();

        // deterministic sim-time tracer (trace.path): installed for this
        // node thread only; every span below carries modeled durations,
        // never wall clock, so the file is a pure function of the seed
        let tracer = cfg
            .trace_path
            .as_ref()
            .map(|_| std::rc::Rc::new(crate::trace::Tracer::new(rank, cfg.trace_buf)));
        let _trace_guard = tracer.clone().map(crate::trace::install);

        // --- per-node setup -------------------------------------------------
        let with_eval = cfg.eval_every > 0 && rank == 0;
        let engine = Engine::load(&cfg.art_dir, &cfg.model, with_eval)?;
        let mut corpus_cfg = CorpusConfig::for_vocab(meta.vocab, cfg.corpus_seed);
        if let Some(noise) = cfg.corpus_noise {
            corpus_cfg.noise = noise;
        }
        let corpus = Corpus::new(corpus_cfg);

        // full compute copy + fp32 master of the own shard
        let mut params = match &cfg.init_params {
            Some(p) => {
                anyhow::ensure!(p.len() == total, "init_params length mismatch");
                p.clone()
            }
            None => meta.init_params(cfg.seed),
        };
        let mut master = params[my_range.clone()].to_vec();

        let shard_tensors = meta.layout.tensors_in(&my_range);
        let mut opt = optim::build(&cfg.optim, my_range.len(), &shard_tensors);
        // Zero-2 modes exchange gradients through the (possibly
        // hierarchical, possibly bucketed) sync engine; DDP keeps the
        // legacy encoder pair only for state accounting.
        let (sync, ddp_pair) = match cfg.mode {
            Mode::Ddp => (
                None,
                Some(compress::build(&cfg.compressor, &meta.layout, my_range.clone(), n)),
            ),
            _ => (
                Some(HierSyncEngine::new(&cfg.compressor, &meta.layout, part, topo, rank)?),
                None,
            ),
        };
        if tracer.is_some() {
            if let Some(se) = &sync {
                se.set_telemetry(true);
            }
        }
        let mut powersgd = if cfg.compressor.method == Method::PowerSgd {
            Some(PowerSgd::new(&meta.layout, cfg.compressor.rank, cfg.seed ^ 0x505753))
        } else {
            None
        };

        // per-rank RNG for the modeled fault-wait jitter. It is advanced
        // exactly once per step whether or not faults are configured, so
        // its stream position is a pure function of the step count —
        // which is what makes it checkpointable.
        let mut node_rng = crate::util::rng::Rng::new(
            cfg.seed ^ 0xFA17 ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );

        // --- checkpoint restore (checkpoint.resume_from) ----------------
        // everything downstream is keyed by the absolute step (corpus
        // microbatches, lr schedule, compressor reset cadence), so after
        // restoring the per-rank state the loop simply starts at ck.step.
        let start_step = match resume {
            Some(ck) => {
                let rs = &ck.ranks[rank];
                anyhow::ensure!(
                    rs.master.len() == my_range.len(),
                    "checkpoint shard for rank {rank} has {} params, this partition wants {}",
                    rs.master.len(),
                    my_range.len()
                );
                params.copy_from_slice(&ck.params);
                master.copy_from_slice(&rs.master);
                opt.import_state(&rs.opt)
                    .with_context(|| format!("rank {rank}: optimizer state"))?;
                if let Some(se) = &sync {
                    se.import_state(&rs.engine)
                        .with_context(|| format!("rank {rank}: sync-engine state"))?;
                }
                node_rng = crate::util::rng::Rng::from_state(&rs.rng);
                ck.step
            }
            None => 0,
        };

        let mut grad = vec![0.0f32; total];
        let mut grad_tmp = vec![0.0f32; total];
        let mut shard_acc = vec![0.0f32; my_range.len()];
        let mut metrics = if rank == 0 { Some(RunMetrics::new()) } else { None };

        // validation loss of a parameter view (rank 0 only) — shared by
        // the periodic in-loop evals and the post-loop final eval so the
        // two can never drift apart
        let eval_val = |ps: &[f32]| -> Result<f64> {
            let mut acc = 0.0f64;
            for b in 0..cfg.eval_batches {
                let tokens = corpus.batch(Split::Val, 0, b as u64, meta.batch, meta.seq);
                acc += engine.eval_loss(ps, &tokens)? as f64;
            }
            Ok(acc / cfg.eval_batches.max(1) as f64)
        };

        // --- async parameter sync state (sync_params = "async") ---------
        // `params` is the compute view the forward pass reads; the drain
        // writes the gathered (one-step-fresher) parameters into the back
        // buffer and the two are swapped — every element is overwritten
        // at each drain, so staleness is always exactly one step and
        // never compounds.
        let async_params = cfg.sync_params == SyncParams::Async && cfg.mode != Mode::Ddp;
        let mut params_back = if async_params { params.clone() } else { Vec::new() };
        let mut pending: Option<PendingHierParams> = None;
        // sim-time instant the in-flight gather's launch completed: start
        // of its `param_window` span (the window the wire has to itself)
        let mut param_window_t0 = 0u64;
        // wall-clock instant the last launch completed: the launch→drain
        // interval is the window the in-flight gather has to itself
        // (RunMetrics::param_sync_window_s)
        let mut launched_at: Option<util::timer::Stopwatch> = None;
        let mut param_wait_s = 0.0f64;
        let mut param_launch_s = 0.0f64;
        let mut param_window_s = 0.0f64;
        let mut stale_steps = 0u64;

        // --- stale gradient state (grad_sync = "stale") -----------------
        // the exchange launched after step k's backward is drained at
        // step k+1 (or after the loop, for the final step) and its
        // one-step-stale average feeds that step's optimizer update
        let mut pending_grads: Option<PendingHierGrads> = None;
        // sim-time instant the in-flight exchange's launch completed:
        // start of its `grad_window` span
        let mut grad_window_t0 = 0u64;
        let mut grad_wait_s = 0.0f64;
        let mut grad_launch_s = 0.0f64;
        let mut grad_stale_steps = 0u64;
        let mut grad_sync_rounds = 0u64;

        // --- local-step state (grad_sync = "local:H") -------------------
        // inner SGD runs on the full local `params` view; the round's
        // pseudo-gradient (round_base − params, normalized by the summed
        // inner lrs) goes through the compressors at round end
        let local_h = match cfg.grad_sync {
            GradSync::Local(h) => h.max(1),
            _ => 0,
        };
        let mut round_base = if local_h > 0 { params.clone() } else { Vec::new() };
        let mut round_lr_sum = 0.0f64;
        let mut local_degenerate_rounds = 0u64;

        // fp32 byte volume an uncompressed *synchronous* run would send
        // per step across all ranks, for the compression ratio. Summed
        // over the actual partition: under the hierarchical two-level cut
        // shards are uneven, so extrapolating rank 0's shard to everyone
        // would skew the denominator. (Stale mode moves the same bytes;
        // local:H sends 1/H of them — the ratio reflects that.)
        let fp32_step_bytes: u64 = match cfg.mode {
            Mode::Ddp => 2 * 4 * total as u64 * n as u64, // tree up+down, order of magnitude
            _ => part
                .ranges
                .iter()
                .map(|r| {
                    let others = (total - r.len()) as u64;
                    4 * others /*grad a2a*/ + 4 * others /*param ag*/
                })
                .sum(),
        };

        // --- fault replay state (faults.events) -------------------------
        // the schedule is consulted identically on every rank at each
        // step boundary, so contribution decisions are symmetric and need
        // no extra communication. With the schedule empty every derived
        // set is empty and contrib == n: the arithmetic below reduces
        // bitwise to the fault-free trainer.
        let fs = (!cfg.faults.is_empty()).then_some(&cfg.faults);
        let mut defer_streak = 0u64;
        // contributor count of the step whose stale exchange is in
        // flight: the drain divides by the count at *launch* time
        let mut pending_contrib = n;
        let mut fault_wait_s = 0.0f64;
        let mut fault_wait_events = 0u64;
        let mut fault_timeout_events = 0u64;
        let mut fault_skipped_sources = 0u64;
        let mut fault_deferred_updates = 0u64;
        let mut fault_dropped_grads = 0u64;
        let mut degraded_rounds = 0u64;
        let mut ef_reset_events = 0u64;
        let mut rank_death_events = 0u64;
        let mut rank_rejoin_events = 0u64;
        let mut dead_rank_steps = 0u64;
        let mut checkpoint_saves = 0u64;

        // --- training loop --------------------------------------------------
        for step in start_step..cfg.steps {
            // the timing layer (LinkSim stretch) reads the step through
            // the context; the logic layer below reads the schedule
            // directly
            ctx.set_sim_step(step);
            crate::trace::with(|t| t.instant("train", "step_begin", &[("step", step as f64)]));
            let step_salt = node_rng.next_u64();
            let dead = fs.map(|f| f.dead_at(step)).unwrap_or_default();
            let stragglers = fs.map(|f| f.stragglers_at(step)).unwrap_or_default();
            // skip policy: a timed-out straggler ships a zero gradient —
            // its error-feedback residual still rides the exchange, only
            // the fresh gradient is dropped
            let excluded: Vec<usize> = if cfg.fault_policy == FaultPolicy::Skip {
                stragglers.iter().copied().filter(|r| !dead.contains(r)).collect()
            } else {
                Vec::new()
            };
            let contrib = (n - dead.len() - excluded.len()).max(1);
            let contributes = !dead.contains(&rank) && !excluded.contains(&rank);
            // EF reconciliation at death onset: the dying rank's
            // compensation residual describes gradients it will never
            // finish shipping — re-zero it (counted as a quality event)
            // so stale compensation cannot leak into the rejoined run.
            // EF21 is exempt: every receiver's per-source reconstruction
            // mirrors the sender's recursion state, and resetting only
            // the sender would desync them (DESIGN.md §3.10).
            if let Some(f) = fs {
                if f.died_at(rank, step) && cfg.compressor.method != Method::Ef21 {
                    if let Some(se) = &sync {
                        se.reset_state();
                    }
                }
            }

            // 1-2: local gradient with accumulation (dead ranks and
            // timed-out stragglers skip the compute and contribute zero)
            grad.fill(0.0);
            let mut loss_acc = 0.0f64;
            if contributes {
                for a in 0..cfg.accum {
                    let micro = step * cfg.accum as u64 + a as u64;
                    let tokens =
                        corpus.batch(Split::Train, rank, micro, meta.batch, meta.seq);
                    let loss = engine.train_step(&params, &tokens, &mut grad_tmp)?;
                    loss_acc += loss as f64;
                    util::add_assign(&mut grad, &grad_tmp);
                }
                if cfg.accum > 1 {
                    util::scale(&mut grad, 1.0 / cfg.accum as f32);
                }
                if cfg.compressor.elementwise_clip > 0.0 {
                    let c = cfg.compressor.elementwise_clip;
                    for g in grad.iter_mut() {
                        *g = g.clamp(-c, c);
                    }
                }
                // modeled compute span: ~6 flops per parameter per token
                // through the analytic GPU preset (netsim::A100)
                crate::trace::with(|t| {
                    let tokens = (meta.batch * meta.seq * cfg.accum) as f64;
                    t.span(
                        "train",
                        "fwd_bwd",
                        crate::trace::flops_ns(6.0 * total as f64 * tokens),
                        &[("step", step as f64), ("tokens", tokens)],
                    );
                });
            }

            // 3-5: synchronize gradients — or, in stale/local modes,
            // schedule the exchange around the compute (DESIGN.md
            // §"Gradient staleness"). `have_update` is false on steps
            // with no averaged gradient to apply: the stale pipeline
            // fill (step 0) and mid-round local steps.
            let mut have_update = true;
            let mut deferred = false;
            let mut update_lr = cfg.lr.at(step);
            match cfg.mode {
                Mode::Zero2 => match cfg.grad_sync {
                    GradSync::Sync => {
                        let mut ts = 0;
                        crate::trace::with(|t| ts = t.now_ns());
                        let t_sync = util::timer::Stopwatch::start();
                        sync.as_ref()
                            .expect("Zero2 has a sync engine")
                            .sync(ctx, &mut grad, &mut shard_acc, step + 1);
                        if let Some(m) = metrics.as_mut() {
                            m.encode_hist.record(t_sync.elapsed().as_secs_f64());
                        }
                        crate::trace::with(|t| {
                            t.span_at(ts, "train", "grad_sync", &[("step", step as f64)]);
                        });
                        util::scale(&mut shard_acc, 1.0 / contrib as f32);
                        grad_sync_rounds += 1;
                    }
                    GradSync::Stale => {
                        let se = sync.as_ref().expect("Zero2 has a sync engine");
                        // defer policy: leave the in-flight exchange on
                        // the wire and run another step on the stale
                        // view; this step's fresh gradients are dropped.
                        // The decision reads only the schedule and the
                        // deterministic streak counter, so every rank
                        // defers in lockstep.
                        if cfg.fault_policy == FaultPolicy::Defer
                            && !stragglers.is_empty()
                            && defer_streak < cfg.max_defer
                            && pending_grads.is_some()
                        {
                            defer_streak += 1;
                            deferred = true;
                            have_update = false;
                        } else {
                            defer_streak = 0;
                            // launch step k's exchange before draining
                            // step k-1's: its wire window then spans the
                            // drain, the optimizer step and the whole
                            // next forward/backward; disjoint per-step
                            // tags keep the two exchanges apart
                            let mut ts = 0;
                            crate::trace::with(|t| ts = t.now_ns());
                            let t_launch = util::timer::Stopwatch::start();
                            let next = se.grad_sync_launch(ctx, &mut grad, step + 1);
                            let launch_el = t_launch.elapsed().as_secs_f64();
                            grad_launch_s += launch_el;
                            if let Some(m) = metrics.as_mut() {
                                m.launch_hist.record(launch_el);
                            }
                            let mut next_window_t0 = 0;
                            crate::trace::with(|t| {
                                t.span_at(ts, "train", "grad_launch", &[("step", step as f64)]);
                                next_window_t0 = t.now_ns();
                            });
                            let next_contrib = contrib;
                            match pending_grads.replace(next) {
                                Some(p) => {
                                    // apply the stale gradient with the lr
                                    // of the step it was computed at, so
                                    // the trajectory is the synchronous
                                    // one with a one-step lag rather than
                                    // an lr shift
                                    update_lr = cfg.lr.at(p.step().saturating_sub(1));
                                    crate::trace::with(|t| {
                                        t.span_at(
                                            grad_window_t0,
                                            "train",
                                            "grad_window",
                                            &[("step", step as f64)],
                                        );
                                    });
                                    let mut td = 0;
                                    crate::trace::with(|t| td = t.now_ns());
                                    let wait = se.grad_sync_drain(ctx, p, &mut shard_acc);
                                    let wait_el = wait.as_secs_f64();
                                    grad_wait_s += wait_el;
                                    if let Some(m) = metrics.as_mut() {
                                        m.wait_hist.record(wait_el);
                                    }
                                    crate::trace::with(|t| {
                                        t.span_at(
                                            td,
                                            "train",
                                            "grad_drain",
                                            &[("step", step as f64)],
                                        );
                                    });
                                    // divide by the contributor count of
                                    // the launch step, not this one
                                    util::scale(
                                        &mut shard_acc,
                                        1.0 / pending_contrib as f32,
                                    );
                                    grad_stale_steps += 1;
                                    grad_sync_rounds += 1;
                                }
                                None => have_update = false, // pipeline fill (step 0)
                            }
                            pending_contrib = next_contrib;
                            grad_window_t0 = next_window_t0;
                        }
                    }
                    GradSync::Local(h) => {
                        // inner step: plain SGD on the full local view;
                        // across a round the nodes' views diverge and the
                        // round-end exchange re-converges them
                        let lr = cfg.lr.at(step);
                        for (p, g) in params.iter_mut().zip(grad.iter()) {
                            *p -= lr * g;
                        }
                        round_lr_sum += lr as f64;
                        if ((step + 1) % h == 0 || step + 1 == cfg.steps)
                            && round_lr_sum > 0.0
                        {
                            // pseudo-gradient: the round's parameter
                            // delta, normalized by the summed inner lrs
                            // so its magnitude (and the wire scale s)
                            // matches an ordinary averaged gradient;
                            // H = 1 reduces to the synchronous schedule
                            // a rank dead (or skipped) at the round
                            // boundary ships a zero pseudo-gradient: even
                            // if it moved earlier in the round while
                            // alive, its partial delta is dropped with
                            // the rest of its contribution
                            if contributes {
                                let inv = 1.0 / round_lr_sum as f32;
                                for (g, (&b, &p)) in
                                    grad.iter_mut().zip(round_base.iter().zip(params.iter()))
                                {
                                    *g = (b - p) * inv;
                                }
                            } else {
                                grad.fill(0.0);
                            }
                            let mut ts = 0;
                            crate::trace::with(|t| ts = t.now_ns());
                            let t_sync = util::timer::Stopwatch::start();
                            sync.as_ref()
                                .expect("Zero2 has a sync engine")
                                .sync(ctx, &mut grad, &mut shard_acc, step + 1);
                            if let Some(m) = metrics.as_mut() {
                                m.encode_hist.record(t_sync.elapsed().as_secs_f64());
                            }
                            crate::trace::with(|t| {
                                t.span_at(ts, "train", "grad_sync", &[("step", step as f64)]);
                            });
                            util::scale(&mut shard_acc, 1.0 / contrib as f32);
                            grad_sync_rounds += 1;
                        } else {
                            // mid-round — or a *degenerate* round whose
                            // inner lrs summed to zero: the parameters
                            // never moved, so the pseudo-gradient is
                            // identically zero. Skip the exchange
                            // entirely (shipping it would pay the wire,
                            // evolve the error feedback and reset it on
                            // reset steps — all for a zero update) and
                            // count it; round_lr_sum stays zero, so the
                            // next round accumulates from the same base.
                            // The lr schedule is deterministic and
                            // identical on every rank, so all ranks skip
                            // in lockstep.
                            if (step + 1) % h == 0 || step + 1 == cfg.steps {
                                local_degenerate_rounds += 1;
                            }
                            have_update = false;
                        }
                    }
                },
                Mode::Zero2ReduceScatter => {
                    ctx.ring_reduce_scatter(&mut grad, &part.ranges);
                    shard_acc.copy_from_slice(&grad[my_range.clone()]);
                    util::scale(&mut shard_acc, 1.0 / n as f32);
                    grad_sync_rounds += 1;
                }
                Mode::Ddp => {
                    if let Some(ps) = powersgd.as_mut() {
                        let mut p1 = ps.phase1(&grad);
                        ctx.tree_all_reduce(&mut p1);
                        util::scale(&mut p1, 1.0 / n as f32);
                        let mut q1 = ps.phase2(&p1);
                        ctx.tree_all_reduce(&mut q1);
                        util::scale(&mut q1, 1.0 / n as f32);
                        ps.finish(&q1, &mut shard_acc);
                    } else {
                        ctx.tree_all_reduce(&mut grad);
                        shard_acc.copy_from_slice(&grad);
                        util::scale(&mut shard_acc, 1.0 / n as f32);
                    }
                    grad_sync_rounds += 1;
                }
            }

            // per-step compression-quality counter tracks (‖e_t‖, pre/post
            // quantization error, auto_scale EMA), pulled from whatever
            // encoders ran this step — zero cost with tracing off
            crate::trace::with(|t| {
                if let Some(se) = &sync {
                    if let Some(tel) = se.take_telemetry() {
                        if tel.elems > 0 {
                            t.counter("loco/ef_norm", tel.ef_norm());
                            t.counter("loco/comp_err_rms", tel.comp_err_rms());
                            t.counter("loco/comp_err_rel", tel.comp_err_rel());
                            t.counter("loco/auto_scale_ema", tel.auto_scale_ema);
                        }
                    }
                }
            });

            if have_update {
                // drain the parameter gather launched after the previous
                // optimizer step: its messages rode the wire while this
                // step's forward/backward ran. The compute view flips to
                // the post-step-(k-1) parameters here — one step stale
                // relative to the synchronous schedule, applied as full
                // owner shards (never deltas), so the lag cannot
                // accumulate. Skipped steps (no optimizer update) never
                // have a handle outstanding: launches only follow
                // updates.
                if let Some(p) = pending.take() {
                    if let Some(t0) = launched_at.take() {
                        param_window_s += t0.elapsed().as_secs_f64();
                    }
                    crate::trace::with(|t| {
                        t.span_at(
                            param_window_t0,
                            "train",
                            "param_window",
                            &[("step", step as f64)],
                        );
                    });
                    let mut td = 0;
                    crate::trace::with(|t| td = t.now_ns());
                    let wait = sync
                        .as_ref()
                        .expect("async param sync runs on the Zero-2 engine")
                        .param_sync_drain(ctx, p, &mut params_back);
                    std::mem::swap(&mut params, &mut params_back);
                    let wait_el = wait.as_secs_f64();
                    param_wait_s += wait_el;
                    if let Some(m) = metrics.as_mut() {
                        m.wait_hist.record(wait_el);
                    }
                    crate::trace::with(|t| {
                        t.span_at(td, "train", "param_drain", &[("step", step as f64)]);
                    });
                }

                // global-norm clip (exact: scalar all-reduce of shard norms)
                if cfg.global_clip > 0.0 {
                    let local_sq: f64 = match cfg.mode {
                        Mode::Ddp => {
                            if rank == 0 {
                                shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum()
                            } else {
                                0.0
                            }
                        }
                        _ => shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum(),
                    };
                    let norm = ctx.tree_all_reduce_scalar(local_sq).sqrt();
                    if norm > cfg.global_clip as f64 {
                        util::scale(&mut shard_acc, (cfg.global_clip as f64 / norm) as f32);
                    }
                }

                // 6: optimizer on the fp32 master shard
                opt.step(&mut master, &shard_acc, update_lr);
                // modeled Adam update: ~28 bytes of memory traffic per
                // shard element (read grad + param, rw two moments)
                crate::trace::with(|t| {
                    t.span(
                        "train",
                        "optimizer",
                        crate::trace::mem_ns(28.0 * master.len() as f64),
                        &[("step", step as f64)],
                    );
                });

                // 7: parameter synchronization — through the engine, so
                // the gather is bucketed/tagged whenever the gradient
                // path is, and two-level (inter peer gather + island
                // broadcast) on hierarchical topologies. In async mode
                // the gather is only *launched* here; the next step's
                // forward runs on the stale view and the drain above
                // completes it.
                match cfg.mode {
                    Mode::Ddp => {
                        // all nodes applied the same update; params == master
                        params.copy_from_slice(&master);
                    }
                    _ => {
                        let bf16 = cfg.param_sync == ParamSync::Bf16;
                        let se = sync.as_ref().expect("Zero-2 has a sync engine");
                        if async_params {
                            // final step: nothing would drain the handle —
                            // the post-loop fp32 master all-gather produces
                            // the final parameters on a clean wire
                            if step + 1 < cfg.steps {
                                let mut ts = 0;
                                crate::trace::with(|t| ts = t.now_ns());
                                let t_launch = util::timer::Stopwatch::start();
                                pending =
                                    Some(se.param_sync_launch(ctx, &master, step + 1, bf16));
                                let launch_el = t_launch.elapsed().as_secs_f64();
                                param_launch_s += launch_el;
                                if let Some(m) = metrics.as_mut() {
                                    m.launch_hist.record(launch_el);
                                }
                                crate::trace::with(|t| {
                                    t.span_at(
                                        ts,
                                        "train",
                                        "param_launch",
                                        &[("step", step as f64)],
                                    );
                                    param_window_t0 = t.now_ns();
                                });
                                launched_at = Some(util::timer::Stopwatch::start());
                                stale_steps += 1;
                            }
                        } else {
                            let mut ts = 0;
                            crate::trace::with(|t| ts = t.now_ns());
                            let t_gather = util::timer::Stopwatch::start();
                            se.param_sync(ctx, &master, &mut params, step + 1, bf16);
                            param_wait_s += t_gather.elapsed().as_secs_f64();
                            crate::trace::with(|t| {
                                t.span_at(ts, "train", "param_sync", &[("step", step as f64)]);
                            });
                        }
                    }
                }

                // local:H: the gathered view is the next round's baseline
                if local_h > 0 {
                    round_base.copy_from_slice(&params);
                    round_lr_sum = 0.0;
                }
            }

            // --- metrics / eval --------------------------------------------
            let mean_loss =
                ctx.tree_all_reduce_scalar(loss_acc / cfg.accum as f64) / contrib as f64;
            // periodic evals score the current compute view (possibly
            // one step stale in async mode, mid-round in local:H); the
            // *final* eval runs after the loop on the gathered fp32
            // masters so the reported val loss always corresponds to
            // `final_params` — with `sync_params = "async"` the in-loop
            // view is one step stale at the last step (the final launch
            // is skipped), and in stale/local grad modes the last
            // optimizer update lands only after the loop.
            let do_eval = cfg.eval_every > 0
                && step % cfg.eval_every == cfg.eval_every - 1
                && step + 1 != cfg.steps;
            let val = if do_eval {
                let mut ts = 0;
                crate::trace::with(|t| ts = t.now_ns());
                let v = if rank == 0 { eval_val(&params)? } else { 0.0 };
                let reduced = ctx.tree_all_reduce_scalar(v);
                crate::trace::with(|t| {
                    if rank == 0 {
                        // modeled forward-only cost of the eval batches
                        let tokens = (cfg.eval_batches * meta.batch * meta.seq) as f64;
                        t.advance_ns(crate::trace::flops_ns(2.0 * total as f64 * tokens));
                    }
                    t.span_at(ts, "train", "eval", &[("step", step as f64)]);
                });
                Some(reduced)
            } else {
                None
            };

            if let Some(m) = metrics.as_mut() {
                if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                    m.train_loss.push(step, mean_loss);
                }
                if let Some(v) = val {
                    m.val_loss.push(step, v);
                }
                m.comm_bytes_fp32 += fp32_step_bytes;
            }

            // --- fault accounting (rank 0; derived from the schedule,
            // which every rank reads identically — no extra traffic) ----
            if rank == 0 {
                if let Some(f) = fs {
                    dead_rank_steps += dead.len() as u64;
                    for r in 0..n {
                        if f.died_at(r, step) {
                            rank_death_events += 1;
                            if cfg.compressor.method != Method::Ef21 {
                                ef_reset_events += 1;
                            }
                        }
                        if f.rejoined_at(r, step) {
                            rank_rejoin_events += 1;
                        }
                    }
                    if !stragglers.is_empty() {
                        let max_slow = stragglers
                            .iter()
                            .map(|&r| f.straggler_slow(r, step))
                            .fold(1.0f64, f64::max);
                        if max_slow > 1.0 {
                            fault_wait_events += 1;
                            // modeled wait: slowdown excess × the drain
                            // budget, jittered deterministically from the
                            // per-step RNG salt (never wall clock)
                            let u = (step_salt >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                            let w = (max_slow - 1.0).min(10.0)
                                * (cfg.drain_timeout_ms as f64 / 1000.0)
                                * (0.5 + u);
                            fault_wait_s += w;
                            crate::trace::with(|t| {
                                t.span(
                                    "collective",
                                    "straggler_wait",
                                    (w * 1e9).round() as u64,
                                    &[("step", step as f64), ("slow", max_slow)],
                                );
                            });
                        }
                    }
                    if !excluded.is_empty() || deferred {
                        fault_timeout_events += 1;
                    }
                    fault_skipped_sources += excluded.len() as u64;
                    if deferred {
                        fault_deferred_updates += 1;
                        fault_dropped_grads += (n - dead.len()) as u64;
                    }
                    if contrib < n {
                        degraded_rounds += 1;
                    }
                }
            }

            // --- checkpoint (checkpoint.save_at) ---------------------------
            // the save is a resync barrier: every in-flight exchange is
            // completed first, so the frozen state is self-contained and
            // the continuing run and a resumed run follow the same
            // trajectory bitwise from this boundary (tests/faults.rs
            // pins save-run ≡ resume-run for every sync mode).
            if cfg.save_at > 0 && step + 1 == cfg.save_at {
                let mut ts = 0;
                crate::trace::with(|t| ts = t.now_ns());
                let se = sync.as_ref().expect("checkpointing runs on the Zero-2 engine");
                if let Some(p) = pending.take() {
                    if let Some(t0) = launched_at.take() {
                        param_window_s += t0.elapsed().as_secs_f64();
                    }
                    let wait = se.param_sync_drain(ctx, p, &mut params_back);
                    param_wait_s += wait.as_secs_f64();
                    std::mem::swap(&mut params, &mut params_back);
                }
                if let Some(p) = pending_grads.take() {
                    let grad_step = p.step().saturating_sub(1);
                    let wait = se.grad_sync_drain(ctx, p, &mut shard_acc);
                    grad_wait_s += wait.as_secs_f64();
                    util::scale(&mut shard_acc, 1.0 / pending_contrib as f32);
                    grad_stale_steps += 1;
                    grad_sync_rounds += 1;
                    if cfg.global_clip > 0.0 {
                        let local_sq: f64 =
                            shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum();
                        let norm = ctx.tree_all_reduce_scalar(local_sq).sqrt();
                        if norm > cfg.global_clip as f64 {
                            util::scale(
                                &mut shard_acc,
                                (cfg.global_clip as f64 / norm) as f32,
                            );
                        }
                    }
                    opt.step(&mut master, &shard_acc, cfg.lr.at(grad_step));
                }
                save_slots.lock().unwrap()[rank] = Some(RankState {
                    master: master.clone(),
                    opt: opt.export_state(),
                    engine: se.export_state(),
                    rng: node_rng.state(),
                });
                // barrier: every slot is filled before rank 0 assembles
                ctx.tree_all_reduce_scalar(0.0);
                if rank == 0 {
                    let ranks: Vec<RankState> = save_slots
                        .lock()
                        .unwrap()
                        .iter_mut()
                        .map(|s| s.take().expect("every rank filled its slot"))
                        .collect();
                    let ck = Checkpoint {
                        step: step + 1,
                        n,
                        total,
                        seed: cfg.seed,
                        corpus_seed: cfg.corpus_seed,
                        params: params.clone(),
                        ranks,
                    };
                    ck.save(cfg.save_path.as_ref().expect("validated in run()"))?;
                    checkpoint_saves += 1;
                }
                // keep peers from racing ahead while the file is written
                ctx.tree_all_reduce_scalar(0.0);
                crate::trace::with(|t| {
                    t.span_at(ts, "train", "checkpoint", &[("step", step as f64)]);
                });
            }
        }

        // grad_sync = "stale": the final step's exchange is still in
        // flight — drain it and apply the last one-step-stale update, so
        // every launched gradient is applied exactly once and a 1-step
        // stale run is bitwise the synchronous run. This mirrors the
        // in-loop drain → scale(1/n) → global-clip → opt.step sequence
        // (stale arm above) and must stay in lockstep with it; the
        // DDP/rank-0 clip special case does not apply here because stale
        // mode is Zero-2 only.
        if let Some(p) = pending_grads.take() {
            let se = sync.as_ref().expect("stale grads run on the Zero-2 engine");
            let grad_step = p.step().saturating_sub(1);
            let mut td = 0;
            crate::trace::with(|t| td = t.now_ns());
            let wait = se.grad_sync_drain(ctx, p, &mut shard_acc);
            let wait_el = wait.as_secs_f64();
            grad_wait_s += wait_el;
            if let Some(m) = metrics.as_mut() {
                m.wait_hist.record(wait_el);
            }
            crate::trace::with(|t| {
                t.span_at(td, "train", "grad_drain", &[("step", cfg.steps as f64)]);
            });
            util::scale(&mut shard_acc, 1.0 / pending_contrib as f32);
            grad_stale_steps += 1;
            grad_sync_rounds += 1;
            if cfg.global_clip > 0.0 {
                let local_sq: f64 = shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum();
                let norm = ctx.tree_all_reduce_scalar(local_sq).sqrt();
                if norm > cfg.global_clip as f64 {
                    util::scale(&mut shard_acc, (cfg.global_clip as f64 / norm) as f32);
                }
            }
            opt.step(&mut master, &shard_acc, cfg.lr.at(grad_step));
        }

        // gather final fp32 master params to rank 0
        if cfg.mode != Mode::Ddp {
            params[my_range.clone()].copy_from_slice(&master);
            ctx.all_gather(&mut params, &part.ranges);
        }

        // final eval on the final parameters (see `do_eval` above): the
        // last val entry is exactly `eval_loss(final_params)`
        if with_eval && cfg.steps > 0 {
            let v = eval_val(&params)?;
            if let Some(m) = metrics.as_mut() {
                m.val_loss.push(cfg.steps - 1, v);
            }
        }

        // park the finished trace for the coordinator to serialize in
        // rank order (the same slot pattern as the checkpoint barrier)
        if let Some(tr) = &tracer {
            trace_slots.lock().unwrap()[rank] = Some(tr.finish());
        }

        if let Some(mut m) = metrics {
            m.steps = cfg.steps;
            m.elapsed = t0.elapsed().as_secs_f64();
            m.tokens_per_sec = (meta.tokens_per_step(n, cfg.accum) as f64 * cfg.steps as f64)
                / m.elapsed.max(1e-9);
            m.compressor_state_bytes = match (&sync, &ddp_pair) {
                (Some(s), _) => s.state_bytes(),
                (None, Some((e, d))) => e.state_bytes() + d.state_bytes(),
                _ => 0,
            };
            m.param_sync_wait_s = param_wait_s;
            m.param_sync_launch_s = param_launch_s;
            m.param_sync_window_s = param_window_s;
            m.param_stale_steps = stale_steps;
            m.grad_sync_wait_s = grad_wait_s;
            m.grad_sync_launch_s = grad_launch_s;
            m.grad_stale_steps = grad_stale_steps;
            m.grad_sync_rounds = grad_sync_rounds;
            m.local_degenerate_rounds = local_degenerate_rounds;
            m.fault_wait_s = fault_wait_s;
            m.fault_wait_events = fault_wait_events;
            m.fault_timeout_events = fault_timeout_events;
            m.fault_skipped_sources = fault_skipped_sources;
            m.fault_deferred_updates = fault_deferred_updates;
            m.fault_dropped_grads = fault_dropped_grads;
            m.degraded_rounds = degraded_rounds;
            m.ef_reset_events = ef_reset_events;
            m.rank_death_events = rank_death_events;
            m.rank_rejoin_events = rank_rejoin_events;
            m.dead_rank_steps = dead_rank_steps;
            m.checkpoint_saves = checkpoint_saves;
            m.resumed_from_step = start_step;
            Ok(Some(RunResult { metrics: m, final_params: params }))
        } else {
            Ok(None)
        }
    }
}

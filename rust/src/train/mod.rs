//! The distributed trainer: N in-process "GPU nodes", each computing
//! gradients through its own PJRT engine (L2/L1 HLO), exchanging them
//! through the compressed collectives, and updating its Zero-2 parameter
//! shard.
//!
//! Data flow per optimizer step on node `n` (Sec. 3 of the paper):
//!
//! 1. `accum` fused fwd+bwd executions on local microbatches (L2 graph);
//! 2. local gradient average, optional element-wise clip (Sec. 5.2);
//! 3. **compress** each destination shard with the configured method
//!    (LoCo: Algorithm 1 steps 1–2);
//! 4. **all-to-all** exchange of low-bit shards (Sec. 3.3 — avoids the
//!    repeated quantize/dequantize of ring reduce-scatter);
//! 5. decode + fp32 average of the N received shards (Eqn. 8),
//!    optional global-norm clip (scalar tree all-reduce);
//! 6. optimizer step on the fp32 *master* copy of the own shard;
//! 7. parameter all-gather at `param_sync` precision (bf16 by default,
//!    matching the paper's b_w = 16).
//!
//! With `sync_params = "async"` step 7 is split: the gather is *launched*
//! after the optimizer step (non-blocking tagged sends), the next step's
//! forward/backward runs against a double-buffered one-step-stale
//! parameter view, and the handle is drained only before the next
//! optimizer step — hiding the gather behind compute (0/1 Adam-style
//! bounded staleness; DESIGN.md §"Async parameter sync").
//!
//! `grad_sync` generalizes the same launch → compute → drain lifecycle
//! to steps 3–5 (DESIGN.md §"Gradient staleness"):
//! * `"stale"` launches the compressed all-to-all right after step k's
//!   backward and drains it at step k+1, applying the one-step-stale
//!   averaged gradient (error feedback intact) — the 0/1 Adam schedule.
//!   The final step's exchange drains after the loop, so every gradient
//!   is applied exactly once.
//! * `"local:H"` runs H local SGD steps between exchanges and ships the
//!   round's accumulated *pseudo-gradient* (the parameter delta,
//!   normalized by the summed inner learning rates) through the same
//!   LoCo compressors — H× fewer exchanges on the wire (DiLoCo /
//!   SparseLoCo lineage).
//! `"sync"` (the default) is bitwise identical to the pre-stale trainer.
//!
//! DDP mode (Table 6 / PowerSGD) replaces 3–5 with a full-gradient
//! all-reduce (tree, or the PowerSGD two-phase protocol) and keeps full
//! optimizer state on every node.

use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::collective::{run_cluster_topo, NodeCtx};
use crate::compress::{
    self, powersgd::PowerSgd, CompressorConfig, Decoder, Encoder, Method,
};
use crate::data::{Corpus, CorpusConfig, Split};
use crate::metrics::RunMetrics;
use crate::model::ModelMeta;
use crate::optim::{self, LrSchedule, OptimConfig};
use crate::runtime::Engine;
use crate::sharding::Partition;
use crate::topology::{HierSyncEngine, PendingHierGrads, PendingHierParams, Topology};
use crate::util;

/// Gradient synchronization topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Zero-2 sharded: compressed all-to-all + param all-gather (default).
    Zero2,
    /// Zero-2 with fp32 ring reduce-scatter (reference path; ignores the
    /// compressor for gradients).
    Zero2ReduceScatter,
    /// Data-parallel with full-gradient tree all-reduce; PowerSGD runs its
    /// two-phase protocol here.
    Ddp,
}

/// Parameter all-gather precision (paper: 16-bit weights on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamSync {
    /// Full-precision parameters on the wire (reference).
    F32,
    /// bf16 parameters on the wire (the paper's b_w = 16 default).
    Bf16,
}

/// When the gathered parameters become visible to the forward pass
/// (`train.sync_params`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncParams {
    /// Gather before the next forward — the paper's schedule, bitwise
    /// identical to the pre-async trainer (default).
    Sync,
    /// One-step-stale: launch the gather right after the optimizer step,
    /// run the next forward/backward against the previous parameter
    /// view, and drain the gather only before the next optimizer step —
    /// the wire carries the parameters while compute runs
    /// (DESIGN.md §"Async parameter sync").
    Async,
}

/// When the gradient exchange runs relative to the optimizer update
/// (`train.grad_sync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradSync {
    /// Exchange and apply in the same step — the paper's schedule,
    /// bitwise identical to the pre-stale trainer (default).
    Sync,
    /// Launch the compressed all-to-all after step k's backward, drain it
    /// during step k+1 and apply the one-step-stale averaged gradient —
    /// the exchange rides the wire while the next forward/backward runs
    /// (0/1 Adam lineage; DESIGN.md §"Gradient staleness").
    Stale,
    /// Run H local SGD steps between exchanges and synchronize the
    /// round's accumulated pseudo-gradient (parameter delta, normalized
    /// by the summed inner learning rates) through the configured
    /// compressors — H× fewer exchanges (DiLoCo / SparseLoCo lineage).
    Local(u64),
}

impl GradSync {
    /// Parse `"sync" | "stale" | "local:H"` (H ≥ 1).
    pub fn parse(s: &str) -> Option<GradSync> {
        match s {
            "sync" => Some(GradSync::Sync),
            "stale" => Some(GradSync::Stale),
            _ => s
                .strip_prefix("local:")
                .and_then(|h| h.parse().ok())
                .filter(|&h| h >= 1)
                .map(GradSync::Local),
        }
    }
}

/// Everything one training run needs.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// model config name (must have artifacts: `model_<name>_*.hlo.txt`)
    pub model: String,
    pub art_dir: PathBuf,
    pub nodes: usize,
    pub steps: u64,
    pub accum: usize,
    pub seed: u64,
    pub mode: Mode,
    pub param_sync: ParamSync,
    /// synchronous vs one-step-stale asynchronous parameter gather
    /// (Zero-2 modes only; `Sync` is bitwise the pre-async trainer)
    pub sync_params: SyncParams,
    /// when the gradient exchange runs: per-step (`Sync`, bitwise the
    /// pre-stale trainer), one step stale (`Stale`), or every H local
    /// steps (`Local(H)`) — Zero-2 mode only for the non-default values
    pub grad_sync: GradSync,
    pub optim: OptimConfig,
    pub lr: LrSchedule,
    pub compressor: CompressorConfig,
    /// number of NVLink islands for the two-level topology (Zero-2 only);
    /// 0/1 = flat cluster, the pre-topology engine bit-for-bit. The
    /// legacy spelling of `tiers = [nodes/islands, islands]`.
    pub islands: usize,
    /// recursive tier tree, innermost (leaf island size) first —
    /// `[4, 2, 2]` = 2 racks of 2 islands of 4 nodes (`topology.tiers`;
    /// Zero-2 only). Empty = use `islands`. `[n]` degrades bitwise to
    /// the flat engine, `[m, k]` to the two-level one.
    pub tiers: Vec<usize>,
    /// explicit uneven leaf islands (`topology.groups`, e.g.
    /// `[[0,1,2],[3,4,5,6,7]]`; Zero-2 only, excludes `tiers`/`islands`)
    pub topo_groups: Vec<Vec<usize>>,
    /// global-norm clip on the averaged gradient (0 = off)
    pub global_clip: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub log_every: u64,
    /// start from these parameters instead of fresh init (fine-tuning)
    pub init_params: Option<Vec<f32>>,
    /// corpus noise level (distribution shift for fine-tuning experiments)
    pub corpus_noise: Option<f64>,
    pub corpus_seed: u64,
}

impl TrainConfig {
    pub fn new(model: &str) -> Self {
        TrainConfig {
            model: model.to_string(),
            art_dir: crate::runtime::artifacts_dir(),
            nodes: 4,
            steps: 100,
            accum: 1,
            seed: 0,
            mode: Mode::Zero2,
            param_sync: ParamSync::Bf16,
            sync_params: SyncParams::Sync,
            grad_sync: GradSync::Sync,
            optim: OptimConfig::default(),
            lr: LrSchedule::constant(1e-3),
            compressor: CompressorConfig::default(),
            islands: 1,
            tiers: Vec::new(),
            topo_groups: Vec::new(),
            global_clip: 1.0,
            eval_every: 0,
            eval_batches: 4,
            log_every: 10,
            init_params: None,
            corpus_noise: None,
            corpus_seed: 1234,
        }
    }
}

/// Result of a run: metrics plus the final full parameter vector.
pub struct RunResult {
    pub metrics: RunMetrics,
    pub final_params: Vec<f32>,
}

/// The multi-node trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg }
    }

    /// Run the configured training job; returns rank-0's metrics and the
    /// final parameters.
    pub fn run(&self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let meta = crate::runtime::load_meta(&cfg.art_dir, &cfg.model)?;
        let n = cfg.nodes;
        let topo = if !cfg.topo_groups.is_empty() {
            anyhow::ensure!(
                cfg.tiers.is_empty() && cfg.islands <= 1,
                "topology.groups excludes topology.tiers and topology.islands"
            );
            Topology::from_groups(n, cfg.topo_groups.clone())?
        } else if !cfg.tiers.is_empty() {
            anyhow::ensure!(
                cfg.islands <= 1,
                "set topology.tiers or topology.islands, not both"
            );
            Topology::from_tiers(n, &cfg.tiers)?
        } else {
            Topology::new(n, cfg.islands.max(1))?
        };
        anyhow::ensure!(
            !topo.is_hierarchical() || cfg.mode == Mode::Zero2,
            "hierarchical topologies (islands / tiers / groups) require train.mode = zero2"
        );
        anyhow::ensure!(
            cfg.sync_params == SyncParams::Sync || cfg.mode != Mode::Ddp,
            "train.sync_params = async requires a Zero-2 mode (DDP has no parameter gather)"
        );
        anyhow::ensure!(
            cfg.grad_sync == GradSync::Sync || cfg.mode == Mode::Zero2,
            "train.grad_sync = stale | local:H requires train.mode = zero2 \
             (the exchange goes through the compressed sync engine)"
        );
        if let GradSync::Local(h) = cfg.grad_sync {
            anyhow::ensure!(h >= 1, "train.grad_sync = local:H needs H >= 1");
            anyhow::ensure!(
                cfg.sync_params == SyncParams::Sync,
                "train.grad_sync = local:H requires train.sync_params = sync \
                 (the round-end gather must complete before the next round's local steps)"
            );
        }
        let part = match cfg.mode {
            Mode::Ddp => Partition { ranges: vec![0..meta.layout.total] },
            Mode::Zero2 if topo.is_hierarchical() => topo.partition(meta.layout.total),
            _ => Partition::tensor_aligned(&meta.layout, n),
        };
        let result0: Mutex<Option<RunResult>> = Mutex::new(None);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

        // flat clusters keep the run_cluster convention (every byte is
        // "inter-island": there is no fast level to hide traffic on);
        // hierarchical ones count bytes per tier level
        let spec = topo.cluster_spec();
        let (_, counters) = run_cluster_topo(n, spec, |ctx| {
            match self.node_main(&ctx, &meta, &part, &topo) {
                Ok(Some(r)) => {
                    *result0.lock().unwrap() = Some(r);
                }
                Ok(None) => {}
                Err(e) => {
                    errors.lock().unwrap().push(format!("node {}: {e:#}", ctx.rank));
                }
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            anyhow::bail!("training failed: {}", errs.join("; "));
        }
        let mut result = result0
            .into_inner()
            .unwrap()
            .context("rank 0 produced no result")?;
        result.metrics.comm_bytes = counters.total_sent();
        result.metrics.comm_bytes_intra = counters.total_intra();
        result.metrics.comm_bytes_inter = counters.total_inter();
        Ok(result)
    }

    fn node_main(
        &self,
        ctx: &NodeCtx,
        meta: &ModelMeta,
        part: &Partition,
        topo: &Topology,
    ) -> Result<Option<RunResult>> {
        let cfg = &self.cfg;
        let rank = ctx.rank;
        let n = ctx.n;
        let total = meta.layout.total;
        let my_range = if cfg.mode == Mode::Ddp { 0..total } else { part.ranges[rank].clone() };
        let t0 = std::time::Instant::now();

        // --- per-node setup -------------------------------------------------
        let with_eval = cfg.eval_every > 0 && rank == 0;
        let engine = Engine::load(&cfg.art_dir, &cfg.model, with_eval)?;
        let mut corpus_cfg = CorpusConfig::for_vocab(meta.vocab, cfg.corpus_seed);
        if let Some(noise) = cfg.corpus_noise {
            corpus_cfg.noise = noise;
        }
        let corpus = Corpus::new(corpus_cfg);

        // full compute copy + fp32 master of the own shard
        let mut params = match &cfg.init_params {
            Some(p) => {
                anyhow::ensure!(p.len() == total, "init_params length mismatch");
                p.clone()
            }
            None => meta.init_params(cfg.seed),
        };
        let mut master = params[my_range.clone()].to_vec();

        let shard_tensors = meta.layout.tensors_in(&my_range);
        let mut opt = optim::build(&cfg.optim, my_range.len(), &shard_tensors);
        // Zero-2 modes exchange gradients through the (possibly
        // hierarchical, possibly bucketed) sync engine; DDP keeps the
        // legacy encoder pair only for state accounting.
        let (sync, ddp_pair) = match cfg.mode {
            Mode::Ddp => (
                None,
                Some(compress::build(&cfg.compressor, &meta.layout, my_range.clone(), n)),
            ),
            _ => (
                Some(HierSyncEngine::new(&cfg.compressor, &meta.layout, part, topo, rank)?),
                None,
            ),
        };
        let mut powersgd = if cfg.compressor.method == Method::PowerSgd {
            Some(PowerSgd::new(&meta.layout, cfg.compressor.rank, cfg.seed ^ 0x505753))
        } else {
            None
        };

        let mut grad = vec![0.0f32; total];
        let mut grad_tmp = vec![0.0f32; total];
        let mut shard_acc = vec![0.0f32; my_range.len()];
        let mut metrics = if rank == 0 { Some(RunMetrics::new()) } else { None };

        // validation loss of a parameter view (rank 0 only) — shared by
        // the periodic in-loop evals and the post-loop final eval so the
        // two can never drift apart
        let eval_val = |ps: &[f32]| -> Result<f64> {
            let mut acc = 0.0f64;
            for b in 0..cfg.eval_batches {
                let tokens = corpus.batch(Split::Val, 0, b as u64, meta.batch, meta.seq);
                acc += engine.eval_loss(ps, &tokens)? as f64;
            }
            Ok(acc / cfg.eval_batches.max(1) as f64)
        };

        // --- async parameter sync state (sync_params = "async") ---------
        // `params` is the compute view the forward pass reads; the drain
        // writes the gathered (one-step-fresher) parameters into the back
        // buffer and the two are swapped — every element is overwritten
        // at each drain, so staleness is always exactly one step and
        // never compounds.
        let async_params = cfg.sync_params == SyncParams::Async && cfg.mode != Mode::Ddp;
        let mut params_back = if async_params { params.clone() } else { Vec::new() };
        let mut pending: Option<PendingHierParams> = None;
        // wall-clock instant the last launch completed: the launch→drain
        // interval is the window the in-flight gather has to itself
        // (RunMetrics::param_sync_window_s)
        let mut launched_at: Option<std::time::Instant> = None;
        let mut param_wait_s = 0.0f64;
        let mut param_launch_s = 0.0f64;
        let mut param_window_s = 0.0f64;
        let mut stale_steps = 0u64;

        // --- stale gradient state (grad_sync = "stale") -----------------
        // the exchange launched after step k's backward is drained at
        // step k+1 (or after the loop, for the final step) and its
        // one-step-stale average feeds that step's optimizer update
        let mut pending_grads: Option<PendingHierGrads> = None;
        let mut grad_wait_s = 0.0f64;
        let mut grad_launch_s = 0.0f64;
        let mut grad_stale_steps = 0u64;
        let mut grad_sync_rounds = 0u64;

        // --- local-step state (grad_sync = "local:H") -------------------
        // inner SGD runs on the full local `params` view; the round's
        // pseudo-gradient (round_base − params, normalized by the summed
        // inner lrs) goes through the compressors at round end
        let local_h = match cfg.grad_sync {
            GradSync::Local(h) => h.max(1),
            _ => 0,
        };
        let mut round_base = if local_h > 0 { params.clone() } else { Vec::new() };
        let mut round_lr_sum = 0.0f64;
        let mut local_degenerate_rounds = 0u64;

        // fp32 byte volume an uncompressed *synchronous* run would send
        // per step across all ranks, for the compression ratio. Summed
        // over the actual partition: under the hierarchical two-level cut
        // shards are uneven, so extrapolating rank 0's shard to everyone
        // would skew the denominator. (Stale mode moves the same bytes;
        // local:H sends 1/H of them — the ratio reflects that.)
        let fp32_step_bytes: u64 = match cfg.mode {
            Mode::Ddp => 2 * 4 * total as u64 * n as u64, // tree up+down, order of magnitude
            _ => part
                .ranges
                .iter()
                .map(|r| {
                    let others = (total - r.len()) as u64;
                    4 * others /*grad a2a*/ + 4 * others /*param ag*/
                })
                .sum(),
        };

        // --- training loop --------------------------------------------------
        for step in 0..cfg.steps {
            // 1-2: local gradient with accumulation
            grad.fill(0.0);
            let mut loss_acc = 0.0f64;
            for a in 0..cfg.accum {
                let micro = step * cfg.accum as u64 + a as u64;
                let tokens = corpus.batch(Split::Train, rank, micro, meta.batch, meta.seq);
                let loss = engine.train_step(&params, &tokens, &mut grad_tmp)?;
                loss_acc += loss as f64;
                util::add_assign(&mut grad, &grad_tmp);
            }
            if cfg.accum > 1 {
                util::scale(&mut grad, 1.0 / cfg.accum as f32);
            }
            if cfg.compressor.elementwise_clip > 0.0 {
                let c = cfg.compressor.elementwise_clip;
                for g in grad.iter_mut() {
                    *g = g.clamp(-c, c);
                }
            }

            // 3-5: synchronize gradients — or, in stale/local modes,
            // schedule the exchange around the compute (DESIGN.md
            // §"Gradient staleness"). `have_update` is false on steps
            // with no averaged gradient to apply: the stale pipeline
            // fill (step 0) and mid-round local steps.
            let mut have_update = true;
            let mut update_lr = cfg.lr.at(step);
            match cfg.mode {
                Mode::Zero2 => match cfg.grad_sync {
                    GradSync::Sync => {
                        sync.as_ref()
                            .expect("Zero2 has a sync engine")
                            .sync(ctx, &mut grad, &mut shard_acc, step + 1);
                        util::scale(&mut shard_acc, 1.0 / n as f32);
                        grad_sync_rounds += 1;
                    }
                    GradSync::Stale => {
                        let se = sync.as_ref().expect("Zero2 has a sync engine");
                        // launch step k's exchange before draining step
                        // k-1's: its wire window then spans the drain,
                        // the optimizer step and the whole next
                        // forward/backward; disjoint per-step tags keep
                        // the two exchanges apart
                        let t_launch = std::time::Instant::now();
                        let next = se.grad_sync_launch(ctx, &mut grad, step + 1);
                        grad_launch_s += t_launch.elapsed().as_secs_f64();
                        match pending_grads.replace(next) {
                            Some(p) => {
                                // apply the stale gradient with the lr of
                                // the step it was computed at, so the
                                // trajectory is the synchronous one with
                                // a one-step lag rather than an lr shift
                                update_lr = cfg.lr.at(p.step().saturating_sub(1));
                                let wait = se.grad_sync_drain(ctx, p, &mut shard_acc);
                                grad_wait_s += wait.as_secs_f64();
                                util::scale(&mut shard_acc, 1.0 / n as f32);
                                grad_stale_steps += 1;
                                grad_sync_rounds += 1;
                            }
                            None => have_update = false, // pipeline fill (step 0)
                        }
                    }
                    GradSync::Local(h) => {
                        // inner step: plain SGD on the full local view;
                        // across a round the nodes' views diverge and the
                        // round-end exchange re-converges them
                        let lr = cfg.lr.at(step);
                        for (p, g) in params.iter_mut().zip(grad.iter()) {
                            *p -= lr * g;
                        }
                        round_lr_sum += lr as f64;
                        if ((step + 1) % h == 0 || step + 1 == cfg.steps)
                            && round_lr_sum > 0.0
                        {
                            // pseudo-gradient: the round's parameter
                            // delta, normalized by the summed inner lrs
                            // so its magnitude (and the wire scale s)
                            // matches an ordinary averaged gradient;
                            // H = 1 reduces to the synchronous schedule
                            let inv = 1.0 / round_lr_sum as f32;
                            for (g, (&b, &p)) in
                                grad.iter_mut().zip(round_base.iter().zip(params.iter()))
                            {
                                *g = (b - p) * inv;
                            }
                            sync.as_ref()
                                .expect("Zero2 has a sync engine")
                                .sync(ctx, &mut grad, &mut shard_acc, step + 1);
                            util::scale(&mut shard_acc, 1.0 / n as f32);
                            grad_sync_rounds += 1;
                        } else {
                            // mid-round — or a *degenerate* round whose
                            // inner lrs summed to zero: the parameters
                            // never moved, so the pseudo-gradient is
                            // identically zero. Skip the exchange
                            // entirely (shipping it would pay the wire,
                            // evolve the error feedback and reset it on
                            // reset steps — all for a zero update) and
                            // count it; round_lr_sum stays zero, so the
                            // next round accumulates from the same base.
                            // The lr schedule is deterministic and
                            // identical on every rank, so all ranks skip
                            // in lockstep.
                            if (step + 1) % h == 0 || step + 1 == cfg.steps {
                                local_degenerate_rounds += 1;
                            }
                            have_update = false;
                        }
                    }
                },
                Mode::Zero2ReduceScatter => {
                    ctx.ring_reduce_scatter(&mut grad, &part.ranges);
                    shard_acc.copy_from_slice(&grad[my_range.clone()]);
                    util::scale(&mut shard_acc, 1.0 / n as f32);
                    grad_sync_rounds += 1;
                }
                Mode::Ddp => {
                    if let Some(ps) = powersgd.as_mut() {
                        let mut p1 = ps.phase1(&grad);
                        ctx.tree_all_reduce(&mut p1);
                        util::scale(&mut p1, 1.0 / n as f32);
                        let mut q1 = ps.phase2(&p1);
                        ctx.tree_all_reduce(&mut q1);
                        util::scale(&mut q1, 1.0 / n as f32);
                        ps.finish(&q1, &mut shard_acc);
                    } else {
                        ctx.tree_all_reduce(&mut grad);
                        shard_acc.copy_from_slice(&grad);
                        util::scale(&mut shard_acc, 1.0 / n as f32);
                    }
                    grad_sync_rounds += 1;
                }
            }

            if have_update {
                // drain the parameter gather launched after the previous
                // optimizer step: its messages rode the wire while this
                // step's forward/backward ran. The compute view flips to
                // the post-step-(k-1) parameters here — one step stale
                // relative to the synchronous schedule, applied as full
                // owner shards (never deltas), so the lag cannot
                // accumulate. Skipped steps (no optimizer update) never
                // have a handle outstanding: launches only follow
                // updates.
                if let Some(p) = pending.take() {
                    if let Some(t0) = launched_at.take() {
                        param_window_s += t0.elapsed().as_secs_f64();
                    }
                    let wait = sync
                        .as_ref()
                        .expect("async param sync runs on the Zero-2 engine")
                        .param_sync_drain(ctx, p, &mut params_back);
                    std::mem::swap(&mut params, &mut params_back);
                    param_wait_s += wait.as_secs_f64();
                }

                // global-norm clip (exact: scalar all-reduce of shard norms)
                if cfg.global_clip > 0.0 {
                    let local_sq: f64 = match cfg.mode {
                        Mode::Ddp => {
                            if rank == 0 {
                                shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum()
                            } else {
                                0.0
                            }
                        }
                        _ => shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum(),
                    };
                    let norm = ctx.tree_all_reduce_scalar(local_sq).sqrt();
                    if norm > cfg.global_clip as f64 {
                        util::scale(&mut shard_acc, (cfg.global_clip as f64 / norm) as f32);
                    }
                }

                // 6: optimizer on the fp32 master shard
                opt.step(&mut master, &shard_acc, update_lr);

                // 7: parameter synchronization — through the engine, so
                // the gather is bucketed/tagged whenever the gradient
                // path is, and two-level (inter peer gather + island
                // broadcast) on hierarchical topologies. In async mode
                // the gather is only *launched* here; the next step's
                // forward runs on the stale view and the drain above
                // completes it.
                match cfg.mode {
                    Mode::Ddp => {
                        // all nodes applied the same update; params == master
                        params.copy_from_slice(&master);
                    }
                    _ => {
                        let bf16 = cfg.param_sync == ParamSync::Bf16;
                        let se = sync.as_ref().expect("Zero-2 has a sync engine");
                        if async_params {
                            // final step: nothing would drain the handle —
                            // the post-loop fp32 master all-gather produces
                            // the final parameters on a clean wire
                            if step + 1 < cfg.steps {
                                let t_launch = std::time::Instant::now();
                                pending =
                                    Some(se.param_sync_launch(ctx, &master, step + 1, bf16));
                                param_launch_s += t_launch.elapsed().as_secs_f64();
                                launched_at = Some(std::time::Instant::now());
                                stale_steps += 1;
                            }
                        } else {
                            let t_gather = std::time::Instant::now();
                            se.param_sync(ctx, &master, &mut params, step + 1, bf16);
                            param_wait_s += t_gather.elapsed().as_secs_f64();
                        }
                    }
                }

                // local:H: the gathered view is the next round's baseline
                if local_h > 0 {
                    round_base.copy_from_slice(&params);
                    round_lr_sum = 0.0;
                }
            }

            // --- metrics / eval --------------------------------------------
            let mean_loss =
                ctx.tree_all_reduce_scalar(loss_acc / cfg.accum as f64) / n as f64;
            // periodic evals score the current compute view (possibly
            // one step stale in async mode, mid-round in local:H); the
            // *final* eval runs after the loop on the gathered fp32
            // masters so the reported val loss always corresponds to
            // `final_params` — with `sync_params = "async"` the in-loop
            // view is one step stale at the last step (the final launch
            // is skipped), and in stale/local grad modes the last
            // optimizer update lands only after the loop.
            let do_eval = cfg.eval_every > 0
                && step % cfg.eval_every == cfg.eval_every - 1
                && step + 1 != cfg.steps;
            let val = if do_eval {
                let v = if rank == 0 { eval_val(&params)? } else { 0.0 };
                Some(ctx.tree_all_reduce_scalar(v))
            } else {
                None
            };

            if let Some(m) = metrics.as_mut() {
                if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                    m.train_loss.push(step, mean_loss);
                }
                if let Some(v) = val {
                    m.val_loss.push(step, v);
                }
                m.comm_bytes_fp32 += fp32_step_bytes;
            }
        }

        // grad_sync = "stale": the final step's exchange is still in
        // flight — drain it and apply the last one-step-stale update, so
        // every launched gradient is applied exactly once and a 1-step
        // stale run is bitwise the synchronous run. This mirrors the
        // in-loop drain → scale(1/n) → global-clip → opt.step sequence
        // (stale arm above) and must stay in lockstep with it; the
        // DDP/rank-0 clip special case does not apply here because stale
        // mode is Zero-2 only.
        if let Some(p) = pending_grads.take() {
            let se = sync.as_ref().expect("stale grads run on the Zero-2 engine");
            let grad_step = p.step().saturating_sub(1);
            let wait = se.grad_sync_drain(ctx, p, &mut shard_acc);
            grad_wait_s += wait.as_secs_f64();
            util::scale(&mut shard_acc, 1.0 / n as f32);
            grad_stale_steps += 1;
            grad_sync_rounds += 1;
            if cfg.global_clip > 0.0 {
                let local_sq: f64 = shard_acc.iter().map(|&x| (x as f64) * (x as f64)).sum();
                let norm = ctx.tree_all_reduce_scalar(local_sq).sqrt();
                if norm > cfg.global_clip as f64 {
                    util::scale(&mut shard_acc, (cfg.global_clip as f64 / norm) as f32);
                }
            }
            opt.step(&mut master, &shard_acc, cfg.lr.at(grad_step));
        }

        // gather final fp32 master params to rank 0
        if cfg.mode != Mode::Ddp {
            params[my_range.clone()].copy_from_slice(&master);
            ctx.all_gather(&mut params, &part.ranges);
        }

        // final eval on the final parameters (see `do_eval` above): the
        // last val entry is exactly `eval_loss(final_params)`
        if with_eval && cfg.steps > 0 {
            let v = eval_val(&params)?;
            if let Some(m) = metrics.as_mut() {
                m.val_loss.push(cfg.steps - 1, v);
            }
        }

        if let Some(mut m) = metrics {
            m.steps = cfg.steps;
            m.elapsed = t0.elapsed().as_secs_f64();
            m.tokens_per_sec = (meta.tokens_per_step(n, cfg.accum) as f64 * cfg.steps as f64)
                / m.elapsed.max(1e-9);
            m.compressor_state_bytes = match (&sync, &ddp_pair) {
                (Some(s), _) => s.state_bytes(),
                (None, Some((e, d))) => e.state_bytes() + d.state_bytes(),
                _ => 0,
            };
            m.param_sync_wait_s = param_wait_s;
            m.param_sync_launch_s = param_launch_s;
            m.param_sync_window_s = param_window_s;
            m.param_stale_steps = stale_steps;
            m.grad_sync_wait_s = grad_wait_s;
            m.grad_sync_launch_s = grad_launch_s;
            m.grad_stale_steps = grad_stale_steps;
            m.grad_sync_rounds = grad_sync_rounds;
            m.local_degenerate_rounds = local_degenerate_rounds;
            Ok(Some(RunResult { metrics: m, final_params: params }))
        } else {
            Ok(None)
        }
    }
}

//! Parameter layout and Zero-2 / FSDP-style sharding.
//!
//! All parameters live in one flat fp32 buffer, tensor by tensor in
//! manifest order. Sharding cuts the flat buffer into N contiguous ranges;
//! [`Partition::tensor_aligned`] places the cuts on tensor boundaries
//! (whole tensors per node, so per-tensor optimizers like Adafactor and
//! LAMB stay exact), while [`Partition::flat_even`] cuts evenly with
//! 2-element alignment (nibble packing needs even shard starts).

use std::ops::Range;

/// One tensor inside the flat parameter buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Ordered tensor table mirroring the python-side manifest.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub tensors: Vec<TensorInfo>,
    pub total: usize,
}

impl ParamLayout {
    pub fn new(named_shapes: Vec<(String, Vec<usize>)>) -> Self {
        let mut tensors = Vec::with_capacity(named_shapes.len());
        let mut offset = 0usize;
        for (name, shape) in named_shapes {
            let len = shape.iter().product::<usize>();
            tensors.push(TensorInfo { name, shape, offset, len });
            offset += len;
        }
        ParamLayout { tensors, total: offset }
    }

    /// Single unnamed flat tensor (tests).
    pub fn single(name: &str, shape: &[usize]) -> Self {
        ParamLayout::new(vec![(name.to_string(), shape.to_vec())])
    }

    pub fn find(&self, name: &str) -> Option<&TensorInfo> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Tensors fully contained in a flat range (with their infos rebased
    /// so `offset` is relative to the range start).
    pub fn tensors_in(&self, range: &Range<usize>) -> Vec<TensorInfo> {
        self.tensors
            .iter()
            .filter(|t| t.offset >= range.start && t.offset + t.len <= range.end)
            .map(|t| TensorInfo { offset: t.offset - range.start, ..t.clone() })
            .collect()
    }
}

/// A cut of `0..total` into `n` contiguous ranges, one per node.
#[derive(Debug, Clone)]
pub struct Partition {
    pub ranges: Vec<Range<usize>>,
}

impl Partition {
    /// Even split with `align`-element alignment on the cut points.
    pub fn flat_even(total: usize, n: usize, align: usize) -> Self {
        assert!(n > 0 && align > 0);
        let mut cuts = Vec::with_capacity(n + 1);
        for i in 0..=n {
            let c = (total * i / n) / align * align;
            cuts.push(if i == n { total } else { c });
        }
        let ranges = (0..n).map(|i| cuts[i]..cuts[i + 1]).collect();
        Partition { ranges }
    }

    /// Split on tensor boundaries, approximately balanced by element count.
    /// Every node receives at least zero tensors; nodes may be empty for
    /// degenerate layouts (more nodes than tensors near the tail).
    pub fn tensor_aligned(layout: &ParamLayout, n: usize) -> Self {
        assert!(n > 0);
        let total = layout.total;
        let mut ranges = Vec::with_capacity(n);
        let mut start = 0usize;
        let mut ti = 0usize;
        for node in 0..n {
            let ideal_end = total * (node + 1) / n;
            let mut end = start;
            while ti < layout.tensors.len() {
                let t = &layout.tensors[ti];
                let t_end = t.offset + t.len;
                // take the tensor if its end is closer to ideal than not
                // taking it, or if later nodes would run out of budget
                if end == start || t_end <= ideal_end
                    || (t_end - ideal_end) < (ideal_end - end)
                {
                    end = t_end;
                    ti += 1;
                    if end >= ideal_end {
                        break;
                    }
                } else {
                    break;
                }
            }
            if node == n - 1 {
                end = total;
                ti = layout.tensors.len();
            }
            ranges.push(start..end);
            start = end;
        }
        Partition { ranges }
    }

    pub fn n(&self) -> usize {
        self.ranges.len()
    }

    /// Which node owns flat index `i`.
    pub fn owner(&self, i: usize) -> usize {
        self.ranges
            .iter()
            .position(|r| r.contains(&i))
            .expect("index out of partition")
    }

    /// Largest shard length (for buffer sizing).
    pub fn max_len(&self) -> usize {
        self.ranges.iter().map(|r| r.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_layout() -> ParamLayout {
        ParamLayout::new(vec![
            ("emb".into(), vec![100, 8]),
            ("w1".into(), vec![8, 32]),
            ("b1".into(), vec![32]),
            ("w2".into(), vec![32, 8]),
            ("head".into(), vec![8, 100]),
        ])
    }

    #[test]
    fn layout_offsets_are_cumulative() {
        let l = demo_layout();
        assert_eq!(l.total, 800 + 256 + 32 + 256 + 800);
        assert_eq!(l.find("b1").unwrap().offset, 800 + 256);
        assert_eq!(l.tensors[0].offset, 0);
    }

    #[test]
    fn flat_even_covers_everything() {
        for total in [0usize, 1, 7, 100, 1001] {
            for n in [1usize, 2, 3, 8] {
                let p = Partition::flat_even(total, n, 2);
                assert_eq!(p.ranges.len(), n);
                assert_eq!(p.ranges[0].start, 0);
                assert_eq!(p.ranges[n - 1].end, total);
                for w in p.ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                // alignment on interior cuts
                for r in &p.ranges[..n - 1] {
                    assert_eq!(r.end % 2, 0);
                }
            }
        }
    }

    #[test]
    fn tensor_aligned_cuts_on_boundaries() {
        let l = demo_layout();
        for n in [1usize, 2, 3, 5] {
            let p = Partition::tensor_aligned(&l, n);
            assert_eq!(p.ranges.len(), n);
            assert_eq!(p.ranges[0].start, 0);
            assert_eq!(p.ranges[n - 1].end, l.total);
            let boundaries: Vec<usize> =
                l.tensors.iter().map(|t| t.offset + t.len).collect();
            for r in &p.ranges {
                if r.end != l.total && !r.is_empty() {
                    assert!(boundaries.contains(&r.end), "cut {} not on boundary", r.end);
                }
            }
        }
    }

    #[test]
    fn tensor_aligned_is_roughly_balanced() {
        let l = demo_layout();
        let p = Partition::tensor_aligned(&l, 2);
        let a = p.ranges[0].len() as f64;
        let b = p.ranges[1].len() as f64;
        assert!(a > 0.0 && b > 0.0);
        assert!(a / (a + b) > 0.3 && a / (a + b) < 0.7, "{a} vs {b}");
    }

    #[test]
    fn tensors_in_rebases_offsets() {
        let l = demo_layout();
        let p = Partition::tensor_aligned(&l, 2);
        let ts = l.tensors_in(&p.ranges[1]);
        assert!(!ts.is_empty());
        assert_eq!(ts[0].offset, 0);
        let covered: usize = ts.iter().map(|t| t.len).sum();
        assert_eq!(covered, p.ranges[1].len());
    }

    #[test]
    fn owner_is_consistent() {
        let p = Partition::flat_even(100, 4, 2);
        for i in 0..100 {
            let o = p.owner(i);
            assert!(p.ranges[o].contains(&i));
        }
    }
}

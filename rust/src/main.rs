//! `loco` — launcher CLI for the LoCo reproduction.
//!
//! Subcommands:
//!   train [--config FILE] [sec.key=val ...]   run a training job
//!   table1 | table8 | throughput              print analytic tables
//!   topology                                  two-tier (NVLink island) model
//!   quant-selftest                            Rust hot path vs L1 kernel
//!   info                                      artifact + config summary
//!
//! (arg parsing is hand-rolled: the offline registry has no `clap`)

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use loco::compress::{CompressorConfig, Method};
use loco::config::Config;
use loco::netsim::{self, throughput::{analytic_throughput_hier, analytic_throughput_hier_async, analytic_throughput_local, analytic_throughput_overlapped, analytic_throughput_stale_hier, local_step_wire_bytes_per_param, paper_speedup, predict_speedup, ACCUMS, PAPER_BASELINES}};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::report::Table;
use loco::train::{GradSync, Mode, ParamSync, SyncParams, TrainConfig, Trainer};
use loco::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("table1") => cmd_table1(),
        Some("table8") => cmd_table8(),
        Some("throughput") => cmd_throughput(),
        Some("topology") => cmd_topology(),
        Some("quant-selftest") => cmd_quant_selftest(),
        Some("info") | None => cmd_info(),
        Some(other) => bail!("unknown subcommand {other:?} (try: train, table1, table8, throughput, topology, quant-selftest, info)"),
    }
}

/// Build a [`TrainConfig`] from a parsed [`Config`] (shared with examples).
pub fn train_config_from(cfg: &Config) -> Result<TrainConfig> {
    let model = cfg.str("train.model", "tiny");
    let mut tc = TrainConfig::new(&model);
    if let Some(dir) = cfg.get("train.artifacts") {
        tc.art_dir = PathBuf::from(dir);
    }
    tc.nodes = cfg.usize("train.nodes", 4)?;
    tc.steps = cfg.u64("train.steps", 100)?;
    tc.accum = cfg.usize("train.accum", 1)?;
    tc.seed = cfg.u64("train.seed", 0)?;
    tc.global_clip = cfg.f32("train.global_clip", 1.0)?;
    tc.eval_every = cfg.u64("train.eval_every", 0)?;
    tc.eval_batches = cfg.usize("train.eval_batches", 4)?;
    tc.log_every = cfg.u64("train.log_every", 10)?;
    tc.corpus_seed = cfg.u64("train.corpus_seed", 1234)?;
    tc.mode = match cfg.str("train.mode", "zero2").as_str() {
        "zero2" => Mode::Zero2,
        "zero2-rs" => Mode::Zero2ReduceScatter,
        "ddp" => Mode::Ddp,
        m => bail!("unknown train.mode {m:?}"),
    };
    tc.param_sync = match cfg.str("train.param_sync", "bf16").as_str() {
        "bf16" => ParamSync::Bf16,
        "fp32" => ParamSync::F32,
        m => bail!("unknown train.param_sync {m:?}"),
    };
    // "sync" gathers before the next forward (bitwise the pre-async
    // trainer); "async" overlaps the gather with the next forward against
    // a one-step-stale parameter view
    tc.sync_params = match cfg.str("train.sync_params", "sync").as_str() {
        "sync" => SyncParams::Sync,
        "async" => SyncParams::Async,
        m => bail!("unknown train.sync_params {m:?} (sync | async)"),
    };
    // "sync" exchanges gradients every step (bitwise the pre-stale
    // trainer); "stale" applies one-step-stale averaged gradients with
    // the exchange hidden behind the next forward/backward; "local:H"
    // runs H local steps per exchange and ships the pseudo-gradient
    let gs = cfg.str("train.grad_sync", "sync");
    tc.grad_sync = GradSync::parse(&gs)
        .with_context(|| format!("unknown train.grad_sync {gs:?} (sync | stale | local:H)"))?;
    // two-level topology: number of NVLink islands (1 = flat)
    tc.islands = cfg.usize("topology.islands", 1)?;

    let kind = cfg.str("optim.kind", "adam");
    let mut oc = OptimConfig {
        kind: OptimizerKind::parse(&kind).with_context(|| format!("optimizer {kind:?}"))?,
        ..OptimConfig::default()
    };
    oc.beta1 = cfg.f32("optim.beta1", 0.9)?;
    oc.beta2 = cfg.f32("optim.beta2", 0.95)?;
    oc.weight_decay = cfg.f32("optim.weight_decay", 0.0)?;
    oc.momentum = cfg.f32("optim.momentum", 0.9)?;
    tc.optim = oc;
    tc.lr = LrSchedule {
        base: cfg.f32("optim.lr", 1e-3)?,
        warmup: cfg.u64("optim.warmup", 10)?,
        total: cfg.u64("optim.lr_total", tc.steps)?,
        min_ratio: cfg.f32("optim.lr_min_ratio", 0.1)?,
    };

    let method = cfg.str("compress.method", "loco");
    let mut cc = CompressorConfig {
        method: Method::parse(&method).with_context(|| format!("method {method:?}"))?,
        ..CompressorConfig::default()
    };
    cc.bits = cfg.usize("compress.bits", 4)? as u32;
    cc.s = cfg.f32("compress.s", cc.s)?;
    cc.s_e_mult = cfg.f32("compress.s_e_mult", 4.0)?;
    cc.beta = cfg.f32("compress.beta", 0.05)?;
    cc.reset_interval = cfg.u64("compress.reset_interval", 512)?;
    cc.error_bits = cfg.usize("compress.error_bits", 8)? as u32;
    cc.no_error_feedback = cfg.bool("compress.no_error_feedback", false)?;
    cc.no_moving_average = cfg.bool("compress.no_moving_average", false)?;
    cc.auto_scale = cfg.bool("compress.auto_scale", false)?;
    cc.block = cfg.usize("compress.block", 256)?;
    cc.rank = cfg.usize("compress.rank", 4)?;
    cc.elementwise_clip = cfg.f32("compress.elementwise_clip", 0.0)?;
    cc.bucket_bytes = match cfg.str("compress.bucket_bytes", "0").as_str() {
        // derive the bucket size from the analytic pipeline model
        // (netsim::throughput::auto_bucket_bytes) instead of a constant
        "auto" => CompressorConfig::AUTO_BUCKET_BYTES,
        v => v.parse().with_context(|| format!("compress.bucket_bytes: bad value {v:?}"))?,
    };
    cc.sync_workers = cfg.usize("compress.sync_workers", 4)?;
    tc.compressor = cc;
    Ok(tc)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = Config::empty();
    let mut i = 0;
    let mut out_csv: Option<PathBuf> = None;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                cfg = Config::load(&PathBuf::from(
                    args.get(i).context("--config needs a path")?,
                ))?;
            }
            "--csv" => {
                i += 1;
                out_csv = Some(PathBuf::from(args.get(i).context("--csv needs a path")?));
            }
            kv if kv.contains('=') => cfg.set_override(kv)?,
            other => bail!("unexpected arg {other:?}"),
        }
        i += 1;
    }
    let tc = train_config_from(&cfg)?;
    println!(
        "training model={} nodes={} steps={} method={} optimizer={}",
        tc.model,
        tc.nodes,
        tc.steps,
        tc.compressor.method.name(),
        tc.optim.kind.name()
    );
    let async_params = tc.sync_params == SyncParams::Async;
    let grad_sync = tc.grad_sync;
    let result = Trainer::new(tc).run()?;
    let m = &result.metrics;
    println!(
        "done: final train loss {:.4}, val loss {:?}, {:.0} tokens/s, comm {} ({}x vs fp32; intra {}, inter {}), compressor state {}",
        m.train_loss.tail_mean(5),
        m.val_loss.last(),
        m.tokens_per_sec,
        loco::util::human_bytes(m.comm_bytes),
        format!("{:.2}", m.compression_ratio()),
        loco::util::human_bytes(m.comm_bytes_intra),
        loco::util::human_bytes(m.comm_bytes_inter),
        loco::util::human_bytes(m.compressor_state_bytes as u64),
    );
    if async_params {
        // overlap efficiency is only meaningful on a real/simulated wire
        // (metrics::RunMetrics::param_overlap_efficiency), so the CLI
        // reports the raw counters
        println!(
            "async param sync: drain wait {:.1} ms, launch {:.1} ms, {} stale forwards",
            1e3 * m.param_sync_wait_s,
            1e3 * m.param_sync_launch_s,
            m.param_stale_steps,
        );
    }
    match grad_sync {
        GradSync::Stale => println!(
            "stale grad sync: drain wait {:.1} ms, launch {:.1} ms, {} stale updates over {} exchanges",
            1e3 * m.grad_sync_wait_s,
            1e3 * m.grad_sync_launch_s,
            m.grad_stale_steps,
            m.grad_sync_rounds,
        ),
        GradSync::Local(h) => println!(
            "local grad sync: H={h} local steps per exchange, {} exchanges over {} steps",
            m.grad_sync_rounds, m.steps,
        ),
        GradSync::Sync => {}
    }
    if let Some(path) = out_csv {
        m.write_csv(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_table1() -> Result<()> {
    let t = netsim::table1::render(7e9, 64.0, 25e9, 4.0);
    println!("{}", t.render());
    Ok(())
}

fn cmd_table8() -> Result<()> {
    let mut t = Table::new(
        "Table 8 — peak memory (GB), paper vs model",
        &["model", "framework", "Adam (paper)", "LoCo (paper)", "LoCo (model)", "rel err"],
    );
    for row in netsim::memory::PAPER_MEMORY {
        let pred = netsim::memory::predict_loco_peak(row.framework, row.params, row.adam_gb);
        t.row(vec![
            row.model.into(),
            row.framework.into(),
            format!("{:.1}", row.adam_gb),
            format!("{:.1}", row.loco_gb),
            format!("{:.1}", pred),
            format!("{:+.1}%", 100.0 * (pred - row.loco_gb) / row.loco_gb),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_throughput() -> Result<()> {
    let mut t = Table::new(
        "Tables 7/11/12 — LoCo speedup over 16-bit Adam, paper vs fitted model",
        &["model", "cluster", "gpus", "accum", "paper", "model", "err"],
    );
    for row in PAPER_BASELINES {
        for (i, &a) in ACCUMS.iter().enumerate() {
            let paper = paper_speedup(row, i) - 1.0;
            let pred = predict_speedup(row, a, "loco") - 1.0;
            t.row(vec![
                row.model.into(),
                row.cluster.into(),
                row.gpus.to_string(),
                format!("{a:.0}"),
                format!("{:.2}%", 100.0 * paper),
                format!("{:.2}%", 100.0 * pred),
                format!("{:+.2}pp", 100.0 * (pred - paper)),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

/// Two-tier analytic model: for each island size, intra traffic (fp32
/// reduce + param broadcast) rides NVLink while the low-bit exchange is
/// pipelined over the inter link — the hierarchical row of the
/// Table-7-style speedup prediction, printed synchronous, asynchronous
/// (`train.sync_params = "async"`) and stale (`train.grad_sync =
/// "stale"`) side by side, plus the local-step wire-volume table
/// (`train.grad_sync = "local:H"`).
fn cmd_topology() -> Result<()> {
    let model = loco::model::analytic_model("llama2-7b").context("analytic model")?;
    let gpus = 64;
    let mbs = 4096.0;
    let buckets = 8;
    let mut t = Table::new(
        "Two-level topology — LoCo over NVLink islands + A800 IB inter-fabric \
         (llama2-7b, 64 GPUs, accum 1, analytic)",
        &[
            "island", "tok/s sync", "tok/s async", "tok/s stale", "comm frac", "async gain",
            "stale gain", "vs flat adam",
        ],
    );
    let (flat_adam, _) = analytic_throughput_overlapped(
        model, netsim::A100, netsim::A800_IB, gpus, mbs, 1.0, "adam", 1,
    );
    for island in [1usize, 2, 4, 8] {
        let (thr, frac) = analytic_throughput_hier(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco", buckets,
        );
        let (thr_async, _) = analytic_throughput_hier_async(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco", buckets,
        );
        let (thr_stale, _) = analytic_throughput_stale_hier(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco",
        );
        t.row(vec![
            format!("{island}x GPUs"),
            format!("{thr:.0}"),
            format!("{thr_async:.0}"),
            format!("{thr_stale:.0}"),
            format!("{:.1}%", 100.0 * frac),
            format!("{:.2}x", thr_async / thr),
            format!("{:.2}x", thr_stale / thr),
            format!("{:.2}x", thr_async / flat_adam),
        ]);
    }
    println!("{}", t.render());
    let mut lt = Table::new(
        "Local-step schedule — H local optimizer steps per exchange \
         (train.grad_sync = \"local:H\"; llama2-7b, 64 GPUs, flat, accum 1, analytic)",
        &["H", "tok/s", "comm frac", "wire B/param/step"],
    );
    for h in [1u64, 2, 4, 8] {
        let (thr, frac) = analytic_throughput_local(
            model, netsim::A100, netsim::A800_IB, gpus, mbs, 1.0, "loco", h, buckets,
        );
        lt.row(vec![
            format!("{h}"),
            format!("{thr:.0}"),
            format!("{:.1}%", 100.0 * frac),
            format!("{:.3}", local_step_wire_bytes_per_param("loco", h)),
        ]);
    }
    println!("{}", lt.render());
    println!(
        "units: tok/s = whole-cluster training tokens per second; comm frac =\n\
         fraction of step wall time spent communicating; async gain = step-time\n\
         win from hiding the inter-island bf16 parameter gather behind the next\n\
         forward pass (train.sync_params = \"async\"); stale gain = win from\n\
         hiding the low-bit gradient exchange instead (train.grad_sync =\n\
         \"stale\", one-step-stale updates) — the two compose in the trainer.\n\
         wire B/param/step = bytes per parameter per optimizer step; local:H\n\
         pays the full 2.25 B/param exchange once per H steps.\n\
         island = 1 is the flat bucketed engine; the hierarchy compresses only the\n\
         inter-island hop, so its win grows with the NVLink/NIC bandwidth gap."
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_quant_selftest() -> Result<()> {
    let art = loco::runtime::artifacts_dir();
    let block = 65536;
    let kernel = loco::runtime::LocoKernel::load(&art, block)
        .context("loading loco_step artifact (run `make artifacts`)")?;
    let mut rng = Rng::new(7);
    let mut g = vec![0.0f32; block];
    rng.fill_normal(&mut g, 0.1);
    let e: Vec<i8> = (0..block).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
    let (s, s_e, beta) = (16.0f32, 64.0f32, 0.125f32);

    let (q_xla, e_xla) = kernel.step(&g, &e, s, s_e, beta, false)?;
    let mut e_rust = e.clone();
    let mut q_rust = vec![0i8; block];
    let p = loco::quant::LocoParams { s, s_e, beta, bits: 4 };
    loco::quant::loco_step(&g, &mut e_rust, &mut q_rust, p, false);

    let q_diff = q_xla.iter().zip(&q_rust).filter(|(a, b)| a != b).count();
    let e_diff = e_xla.iter().zip(&e_rust).filter(|(a, b)| a != b).count();
    println!("loco_step parity over {block} elements: q mismatches={q_diff}, e mismatches={e_diff}");
    if q_diff + e_diff > 0 {
        bail!("Rust hot path disagrees with the L1 Pallas kernel");
    }
    println!("selftest OK — Rust hot path is bit-identical to the Pallas kernel");
    Ok(())
}

/// Without the PJRT backend the true L1 parity check cannot run; verify
/// the two Rust hot paths (scalar fused step and packed wire emitter)
/// against each other instead, which `tests/xla_parity.rs` pins to the
/// kernel whenever the `pjrt` feature is enabled.
#[cfg(not(feature = "pjrt"))]
fn cmd_quant_selftest() -> Result<()> {
    let block = 65536;
    let mut rng = Rng::new(7);
    let mut g = vec![0.0f32; block];
    rng.fill_normal(&mut g, 0.1);
    let e: Vec<i8> = (0..block).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
    let p = loco::quant::LocoParams { s: 16.0, s_e: 64.0, beta: 0.125, bits: 4 };

    let mut e_scalar = e.clone();
    let mut q_scalar = vec![0i8; block];
    loco::quant::loco_step(&g, &mut e_scalar, &mut q_scalar, p, false);
    let mut e_packed = e.clone();
    let mut packed = Vec::new();
    loco::quant::loco_step_packed(&g, &mut e_packed, &mut packed, p, false);

    let q_unpacked = loco::quant::unpack_nibbles(&packed, block);
    let q_diff = q_scalar.iter().zip(&q_unpacked).filter(|(a, b)| a != b).count();
    let e_diff = e_scalar.iter().zip(&e_packed).filter(|(a, b)| a != b).count();
    println!("loco_step scalar vs packed over {block} elements: q mismatches={q_diff}, e mismatches={e_diff}");
    if q_diff + e_diff > 0 {
        bail!("packed wire path disagrees with the scalar reference");
    }
    println!(
        "selftest OK — scalar and packed hot paths agree \
         (enable the `pjrt` feature + `make artifacts` for true L1 kernel parity)"
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("loco — LoCo: Low-Bit Communication Adaptor (reproduction)");
    let art = loco::runtime::artifacts_dir();
    println!("artifacts dir: {}", art.display());
    if art.is_dir() {
        let mut names: Vec<String> = std::fs::read_dir(&art)?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| !n.starts_with('.'))
            .collect();
        names.sort();
        for n in names {
            println!("  {n}");
        }
    } else {
        println!("  (missing — run `make artifacts`)");
    }
    println!("subcommands: train, table1, table8, throughput, topology, quant-selftest, info");
    Ok(())
}

//! `loco` — launcher CLI for the LoCo reproduction.
//!
//! Subcommands:
//!   train [--config FILE] [sec.key=val ...]   run a training job
//!   faults [--config FILE] [--replay] [...]   resolve (and replay) a fault schedule
//!   table1 | table8 | throughput              print analytic tables
//!   topology [--gpus N] [--tiers m0,m1,...]   tiered (island/rack/spine) model
//!   trace FILE                                summarize a --trace output file
//!   quant-selftest                            Rust hot path vs L1 kernel
//!   info                                      artifact + config summary
//!
//! (arg parsing is hand-rolled: the offline registry has no `clap`)

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use loco::collective::{FaultKind, FaultSchedule};
use loco::compress::{CompressorConfig, Method};
use loco::config::Config;
use loco::netsim::{self, throughput::{analytic_throughput_hier, analytic_throughput_hier_async, analytic_throughput_local, analytic_throughput_overlapped, analytic_throughput_stale_hier, analytic_throughput_tiered, analytic_throughput_tiered_async, analytic_throughput_tiered_stale, local_step_wire_bytes_per_param, outer_tier_grad_bytes_per_param, paper_speedup, predict_speedup, ACCUMS, PAPER_BASELINES}};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::report::Table;
use loco::train::{FaultPolicy, GradSync, Mode, ParamSync, SyncParams, TrainConfig, Trainer};
use loco::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("faults") => cmd_faults(&args[1..]),
        Some("table1") => cmd_table1(),
        Some("table8") => cmd_table8(),
        Some("throughput") => cmd_throughput(),
        Some("topology") => cmd_topology(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("quant-selftest") => cmd_quant_selftest(),
        Some("info") | None => cmd_info(),
        Some(other) => bail!("unknown subcommand {other:?} (try: train, faults, table1, table8, throughput, topology, trace, quant-selftest, info)"),
    }
}

/// Build a [`TrainConfig`] from a parsed [`Config`] (shared with examples).
pub fn train_config_from(cfg: &Config) -> Result<TrainConfig> {
    let model = cfg.str("train.model", "tiny");
    let mut tc = TrainConfig::new(&model);
    if let Some(dir) = cfg.get("train.artifacts") {
        tc.art_dir = PathBuf::from(dir);
    }
    tc.nodes = cfg.usize("train.nodes", 4)?;
    tc.steps = cfg.u64("train.steps", 100)?;
    tc.accum = cfg.usize("train.accum", 1)?;
    tc.seed = cfg.u64("train.seed", 0)?;
    tc.global_clip = cfg.f32("train.global_clip", 1.0)?;
    tc.eval_every = cfg.u64("train.eval_every", 0)?;
    tc.eval_batches = cfg.usize("train.eval_batches", 4)?;
    tc.log_every = cfg.u64("train.log_every", 10)?;
    tc.corpus_seed = cfg.u64("train.corpus_seed", 1234)?;
    tc.mode = match cfg.str("train.mode", "zero2").as_str() {
        "zero2" => Mode::Zero2,
        "zero2-rs" => Mode::Zero2ReduceScatter,
        "ddp" => Mode::Ddp,
        m => bail!("unknown train.mode {m:?}"),
    };
    tc.param_sync = match cfg.str("train.param_sync", "bf16").as_str() {
        "bf16" => ParamSync::Bf16,
        "fp32" => ParamSync::F32,
        m => bail!("unknown train.param_sync {m:?}"),
    };
    // "sync" gathers before the next forward (bitwise the pre-async
    // trainer); "async" overlaps the gather with the next forward against
    // a one-step-stale parameter view
    tc.sync_params = match cfg.str("train.sync_params", "sync").as_str() {
        "sync" => SyncParams::Sync,
        "async" => SyncParams::Async,
        m => bail!("unknown train.sync_params {m:?} (sync | async)"),
    };
    // "sync" exchanges gradients every step (bitwise the pre-stale
    // trainer); "stale" applies one-step-stale averaged gradients with
    // the exchange hidden behind the next forward/backward; "local:H"
    // runs H local steps per exchange and ships the pseudo-gradient
    let gs = cfg.str("train.grad_sync", "sync");
    tc.grad_sync = GradSync::parse(&gs)
        .with_context(|| format!("unknown train.grad_sync {gs:?} (sync | stale | local:H)"))?;
    // topology: the legacy two-level island count, a recursive tier
    // list ("4,2,2", innermost first), or explicit uneven islands
    // ("0-2;3-7" — islands separated by ';', members as ranks or a-b
    // ranges). The trainer validates exclusivity and divisibility.
    tc.islands = cfg.usize("topology.islands", 1)?;
    if let Some(t) = cfg.get("topology.tiers") {
        tc.tiers = parse_tier_list(t)?;
    }
    if let Some(g) = cfg.get("topology.groups") {
        tc.topo_groups = parse_group_list(g)?;
    }

    let kind = cfg.str("optim.kind", "adam");
    let mut oc = OptimConfig {
        kind: OptimizerKind::parse(&kind).with_context(|| format!("optimizer {kind:?}"))?,
        ..OptimConfig::default()
    };
    oc.beta1 = cfg.f32("optim.beta1", 0.9)?;
    oc.beta2 = cfg.f32("optim.beta2", 0.95)?;
    oc.weight_decay = cfg.f32("optim.weight_decay", 0.0)?;
    oc.momentum = cfg.f32("optim.momentum", 0.9)?;
    tc.optim = oc;
    tc.lr = LrSchedule {
        base: cfg.f32("optim.lr", 1e-3)?,
        warmup: cfg.u64("optim.warmup", 10)?,
        total: cfg.u64("optim.lr_total", tc.steps)?,
        min_ratio: cfg.f32("optim.lr_min_ratio", 0.1)?,
    };

    let method = cfg.str("compress.method", "loco");
    let mut cc = CompressorConfig {
        method: Method::parse(&method).with_context(|| format!("method {method:?}"))?,
        ..CompressorConfig::default()
    };
    cc.bits = cfg.usize("compress.bits", 4)? as u32;
    cc.s = cfg.f32("compress.s", cc.s)?;
    cc.s_e_mult = cfg.f32("compress.s_e_mult", 4.0)?;
    cc.beta = cfg.f32("compress.beta", 0.05)?;
    cc.reset_interval = cfg.u64("compress.reset_interval", 512)?;
    cc.error_bits = cfg.usize("compress.error_bits", 8)? as u32;
    cc.no_error_feedback = cfg.bool("compress.no_error_feedback", false)?;
    cc.no_moving_average = cfg.bool("compress.no_moving_average", false)?;
    cc.auto_scale = cfg.bool("compress.auto_scale", false)?;
    cc.block = cfg.usize("compress.block", 256)?;
    cc.sparse_k = cfg.usize("compress.sparse_k", 16)?;
    cc.rank = cfg.usize("compress.rank", 4)?;
    cc.elementwise_clip = cfg.f32("compress.elementwise_clip", 0.0)?;
    cc.bucket_bytes = match cfg.str("compress.bucket_bytes", "0").as_str() {
        // derive the bucket size from the analytic pipeline model
        // (netsim::throughput::auto_bucket_bytes) instead of a constant
        "auto" => CompressorConfig::AUTO_BUCKET_BYTES,
        v => v.parse().with_context(|| format!("compress.bucket_bytes: bad value {v:?}"))?,
    };
    cc.sync_workers = cfg.usize("compress.sync_workers", 4)?;
    tc.compressor = cc;

    // --- fault injection + checkpointing --------------------------------
    let fp = cfg.str("train.fault_policy", "wait");
    tc.fault_policy = FaultPolicy::parse(&fp)
        .with_context(|| format!("unknown train.fault_policy {fp:?} (wait | skip | defer)"))?;
    if let Some(spec) = cfg.get("faults.events") {
        let fseed = cfg.u64("faults.seed", tc.seed)?;
        tc.faults = FaultSchedule::parse(spec, fseed)?;
    }
    tc.drain_timeout_ms = cfg.u64("faults.drain_timeout_ms", 100)?;
    tc.max_defer = cfg.u64("faults.max_defer", 3)?;
    if let Some(p) = cfg.get("checkpoint.save_path") {
        tc.save_path = Some(PathBuf::from(p));
    }
    tc.save_at = cfg.u64("checkpoint.save_at", 0)?;
    if let Some(p) = cfg.get("checkpoint.resume_from") {
        tc.resume_from = Some(PathBuf::from(p));
    }
    // --- tracing (DESIGN.md §3.11) --------------------------------------
    if let Some(p) = cfg.get("trace.path") {
        tc.trace_path = Some(PathBuf::from(p));
    }
    tc.trace_buf = cfg.usize("trace.buffer", tc.trace_buf)?;
    Ok(tc)
}

/// Resolve a fault schedule from config/overrides and print it as a
/// table; with `--replay`, additionally run the configured (default:
/// tiny, 12-step) training job under the schedule and print the
/// resilience counters. A malformed `faults.events` spec is a hard error
/// (exit 1), never a silently empty schedule.
fn cmd_faults(args: &[String]) -> Result<()> {
    let mut cfg = Config::empty();
    let mut replay = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                cfg = Config::load(&PathBuf::from(
                    args.get(i).context("--config needs a path")?,
                ))?;
            }
            "--replay" => replay = true,
            kv if kv.contains('=') => cfg.set_override(kv)?,
            other => bail!(
                "unexpected arg {other:?} \
                 (usage: loco faults [--config FILE] [--replay] [sec.key=val ...])"
            ),
        }
        i += 1;
    }
    let mut tc = train_config_from(&cfg)?;
    anyhow::ensure!(
        !tc.faults.is_empty(),
        "no fault schedule: set faults.events \
         (e.g. \"straggler:rank=1:steps=2-5:slow=3\")"
    );
    let mut t = Table::new(
        &format!(
            "fault schedule — seed {}, {} events, policy {}",
            tc.faults.seed,
            tc.faults.events.len(),
            tc.fault_policy.name()
        ),
        &["rank", "kind", "steps", "magnitude"],
    );
    for e in &tc.faults.events {
        let (kind, mag) = match e.kind {
            FaultKind::Straggler { slow } => ("straggler", format!("{slow:.2}x slower egress")),
            FaultKind::Jitter { max } => {
                ("jitter", format!("up to +{:.0}% per message", 100.0 * max))
            }
            FaultKind::Drop => ("drop", "dead (zero gradient)".to_string()),
        };
        t.row(vec![
            e.rank.to_string(),
            kind.into(),
            format!("{}-{}", e.from, e.until),
            mag,
        ]);
    }
    println!("{}", t.render());
    if replay {
        // keep the replay tiny unless the config asked for more
        if cfg.get("train.steps").is_none() {
            tc.steps = 12;
            tc.lr.total = 12;
        }
        println!(
            "replaying {} steps: model={} nodes={} policy={}",
            tc.steps,
            tc.model,
            tc.nodes,
            tc.fault_policy.name()
        );
        let result = Trainer::new(tc).run()?;
        let m = &result.metrics;
        println!("final train loss {:.4}", m.train_loss.tail_mean(5));
        println!(
            "straggler waits: {} events, modeled {:.1} ms; timeouts {}; skipped sources {}",
            m.fault_wait_events,
            1e3 * m.fault_wait_s,
            m.fault_timeout_events,
            m.fault_skipped_sources
        );
        println!(
            "deferred updates {}; dropped grads {}; degraded rounds {}",
            m.fault_deferred_updates, m.fault_dropped_grads, m.degraded_rounds
        );
        println!(
            "rank deaths {}; rejoins {}; dead rank-steps {}; EF resets {}",
            m.rank_death_events, m.rank_rejoin_events, m.dead_rank_steps, m.ef_reset_events
        );
    }
    Ok(())
}

/// Parse a comma-separated tier list (`"4,2,2"`, innermost first).
fn parse_tier_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .with_context(|| format!("bad tier {t:?} (expected e.g. \"4,2,2\")"))
        })
        .collect()
}

/// Parse an uneven-island list: islands separated by `;`, members as
/// single ranks or `a-b` ranges (`"0-2;3-7"` or `"0,1,2;3,4,5,6,7"`).
fn parse_group_list(s: &str) -> Result<Vec<Vec<usize>>> {
    let mut out = Vec::new();
    for island in s.split(';') {
        let mut members = Vec::new();
        for item in island.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            if let Some((a, b)) = item.split_once('-') {
                let a: usize = a
                    .trim()
                    .parse()
                    .with_context(|| format!("topology.groups: bad range start {a:?}"))?;
                let b: usize = b
                    .trim()
                    .parse()
                    .with_context(|| format!("topology.groups: bad range end {b:?}"))?;
                if a > b {
                    bail!("topology.groups: empty range {a}-{b}");
                }
                members.extend(a..=b);
            } else {
                members.push(
                    item.parse()
                        .with_context(|| format!("topology.groups: bad rank {item:?}"))?,
                );
            }
        }
        if members.is_empty() {
            bail!("topology.groups: empty island in {s:?}");
        }
        out.push(members);
    }
    Ok(out)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = Config::empty();
    let mut i = 0;
    let mut out_csv: Option<PathBuf> = None;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                cfg = Config::load(&PathBuf::from(
                    args.get(i).context("--config needs a path")?,
                ))?;
            }
            "--csv" => {
                i += 1;
                out_csv = Some(PathBuf::from(args.get(i).context("--csv needs a path")?));
            }
            "--trace" => {
                i += 1;
                let p = args.get(i).context("--trace needs a path")?;
                cfg.set_override(&format!("trace.path={p}"))?;
            }
            kv if kv.contains('=') => cfg.set_override(kv)?,
            other => bail!("unexpected arg {other:?}"),
        }
        i += 1;
    }
    let tc = train_config_from(&cfg)?;
    println!(
        "training model={} nodes={} steps={} method={} optimizer={}",
        tc.model,
        tc.nodes,
        tc.steps,
        tc.compressor.method.name(),
        tc.optim.kind.name()
    );
    let async_params = tc.sync_params == SyncParams::Async;
    let grad_sync = tc.grad_sync;
    let have_faults = !tc.faults.is_empty();
    let trace_path = tc.trace_path.clone();
    let result = Trainer::new(tc).run()?;
    let m = &result.metrics;
    println!(
        "done: final train loss {:.4}, val loss {:?}, {:.0} tokens/s, comm {} ({}x vs fp32; intra {}, inter {}), compressor state {}",
        m.train_loss.tail_mean(5),
        m.val_loss.last(),
        m.tokens_per_sec,
        loco::util::human_bytes(m.comm_bytes),
        format!("{:.2}", m.compression_ratio()),
        loco::util::human_bytes(m.comm_bytes_intra),
        loco::util::human_bytes(m.comm_bytes_inter),
        loco::util::human_bytes(m.compressor_state_bytes as u64),
    );
    if async_params {
        // overlap efficiency is only meaningful on a real/simulated wire
        // (metrics::RunMetrics::param_overlap_efficiency), so the CLI
        // reports the raw counters
        println!(
            "async param sync: drain wait {:.1} ms, launch {:.1} ms, {} stale forwards",
            1e3 * m.param_sync_wait_s,
            1e3 * m.param_sync_launch_s,
            m.param_stale_steps,
        );
    }
    match grad_sync {
        GradSync::Stale => println!(
            "stale grad sync: drain wait {:.1} ms, launch {:.1} ms, {} stale updates over {} exchanges",
            1e3 * m.grad_sync_wait_s,
            1e3 * m.grad_sync_launch_s,
            m.grad_stale_steps,
            m.grad_sync_rounds,
        ),
        GradSync::Local(h) => println!(
            "local grad sync: H={h} local steps per exchange, {} exchanges over {} steps \
             ({} degenerate zero-lr rounds skipped)",
            m.grad_sync_rounds, m.steps, m.local_degenerate_rounds,
        ),
        GradSync::Sync => {}
    }
    if have_faults {
        println!(
            "faults: {} waits ({:.1} ms modeled), {} timeouts, {} skipped sources, \
             {} deferred updates, {} degraded rounds, {} deaths / {} rejoins \
             ({} dead rank-steps, {} EF resets)",
            m.fault_wait_events,
            1e3 * m.fault_wait_s,
            m.fault_timeout_events,
            m.fault_skipped_sources,
            m.fault_deferred_updates,
            m.degraded_rounds,
            m.rank_death_events,
            m.rank_rejoin_events,
            m.dead_rank_steps,
            m.ef_reset_events
        );
    }
    if m.checkpoint_saves > 0 {
        println!("checkpoints written: {}", m.checkpoint_saves);
    }
    if m.resumed_from_step > 0 {
        println!("resumed from step {}", m.resumed_from_step);
    }
    if let Some(path) = out_csv {
        m.write_csv(&path)?;
        println!("wrote {}", path.display());
    }
    if let Some(path) = trace_path {
        println!(
            "wrote trace {} (load in https://ui.perfetto.dev or chrome://tracing; \
             summarize with `loco trace {}`)",
            path.display(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_table1() -> Result<()> {
    let t = netsim::table1::render(7e9, 64.0, 25e9, 4.0);
    println!("{}", t.render());
    Ok(())
}

fn cmd_table8() -> Result<()> {
    let mut t = Table::new(
        "Table 8 — peak memory (GB), paper vs model",
        &["model", "framework", "Adam (paper)", "LoCo (paper)", "LoCo (model)", "rel err"],
    );
    for row in netsim::memory::PAPER_MEMORY {
        let pred = netsim::memory::predict_loco_peak(row.framework, row.params, row.adam_gb);
        t.row(vec![
            row.model.into(),
            row.framework.into(),
            format!("{:.1}", row.adam_gb),
            format!("{:.1}", row.loco_gb),
            format!("{:.1}", pred),
            format!("{:+.1}%", 100.0 * (pred - row.loco_gb) / row.loco_gb),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_throughput() -> Result<()> {
    let mut t = Table::new(
        "Tables 7/11/12 — LoCo speedup over 16-bit Adam, paper vs fitted model",
        &["model", "cluster", "gpus", "accum", "paper", "model", "err"],
    );
    for row in PAPER_BASELINES {
        for (i, &a) in ACCUMS.iter().enumerate() {
            let paper = paper_speedup(row, i) - 1.0;
            let pred = predict_speedup(row, a, "loco") - 1.0;
            t.row(vec![
                row.model.into(),
                row.cluster.into(),
                row.gpus.to_string(),
                format!("{a:.0}"),
                format!("{:.2}%", 100.0 * paper),
                format!("{:.2}%", 100.0 * pred),
                format!("{:+.2}pp", 100.0 * (pred - paper)),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

/// Tiered analytic model. Without flags: the classic two-level island
/// sweep plus the local-step table. With `--tiers m0,m1[,m2...]`
/// (innermost first) and optionally `--gpus N`: one row per tier of the
/// recursive tree — group size, fan-out, link class and the per-tier
/// wire bytes/param — plus the sync / async (`train.sync_params`) /
/// stale (`train.grad_sync`) throughput rows. A tier list whose product
/// does not equal the GPU count is an error (exit 1), never a silently
/// truncated model.
fn cmd_topology(args: &[String]) -> Result<()> {
    let mut gpus = 64usize;
    let mut tiers: Option<Vec<usize>> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--gpus" => {
                i += 1;
                gpus = args
                    .get(i)
                    .context("--gpus needs a count")?
                    .parse()
                    .context("--gpus: bad count")?;
            }
            "--tiers" => {
                i += 1;
                tiers = Some(parse_tier_list(
                    args.get(i).context("--tiers needs a list like 4,2,2")?,
                )?);
            }
            other => bail!(
                "unexpected arg {other:?} (usage: loco topology [--gpus N] [--tiers m0,m1,...])"
            ),
        }
        i += 1;
    }
    if let Some(tiers) = tiers {
        return cmd_topology_tiers(gpus, &tiers);
    }
    let model = loco::model::analytic_model("llama2-7b").context("analytic model")?;
    let mbs = 4096.0;
    let buckets = 8;
    let mut t = Table::new(
        "Two-level topology — LoCo over NVLink islands + A800 IB inter-fabric \
         (llama2-7b, 64 GPUs, accum 1, analytic)",
        &[
            "island", "tok/s sync", "tok/s async", "tok/s stale", "comm frac", "async gain",
            "stale gain", "vs flat adam",
        ],
    );
    let (flat_adam, _) = analytic_throughput_overlapped(
        model, netsim::A100, netsim::A800_IB, gpus, mbs, 1.0, "adam", 1,
    );
    // only the island sizes that actually divide the cluster: the sweep
    // must keep working for e.g. --gpus 12 (islands 8 would now error
    // instead of silently truncating, so it is skipped, not attempted)
    for island in [1usize, 2, 4, 8].into_iter().filter(|i| gpus % i == 0) {
        let (thr, frac) = analytic_throughput_hier(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco", buckets,
        )?;
        let (thr_async, _) = analytic_throughput_hier_async(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco", buckets,
        )?;
        let (thr_stale, _) = analytic_throughput_stale_hier(
            model, netsim::A100, netsim::NVLINK, netsim::A800_IB,
            gpus, island, mbs, 1.0, "loco",
        )?;
        t.row(vec![
            format!("{island}x GPUs"),
            format!("{thr:.0}"),
            format!("{thr_async:.0}"),
            format!("{thr_stale:.0}"),
            format!("{:.1}%", 100.0 * frac),
            format!("{:.2}x", thr_async / thr),
            format!("{:.2}x", thr_stale / thr),
            format!("{:.2}x", thr_async / flat_adam),
        ]);
    }
    println!("{}", t.render());
    let mut lt = Table::new(
        "Local-step schedule — H local optimizer steps per exchange \
         (train.grad_sync = \"local:H\"; llama2-7b, 64 GPUs, flat, accum 1, analytic)",
        &["H", "tok/s", "comm frac", "wire B/param/step"],
    );
    for h in [1u64, 2, 4, 8] {
        let (thr, frac) = analytic_throughput_local(
            model, netsim::A100, netsim::A800_IB, gpus, mbs, 1.0, "loco", h, buckets,
        );
        lt.row(vec![
            format!("{h}"),
            format!("{thr:.0}"),
            format!("{:.1}%", 100.0 * frac),
            format!("{:.3}", local_step_wire_bytes_per_param("loco", h)),
        ]);
    }
    println!("{}", lt.render());
    let mut mt = Table::new(
        "Compressor wire budgets — flat bucketed engine \
         (llama2-7b, 64 GPUs, accum 1, analytic)",
        &["method", "wire B/param", "grad B/param", "tok/s sync", "vs adam"],
    );
    for method in ["adam", "loco", "zeropp", "sparse"] {
        let total = netsim::wire_bytes_per_param(method);
        let grad = total - netsim::param_wire_bytes_per_param(method).min(total);
        let (thr, _) = analytic_throughput_overlapped(
            model, netsim::A100, netsim::A800_IB, gpus, mbs, 1.0, method, buckets,
        );
        mt.row(vec![
            method.to_string(),
            format!("{total:.3}"),
            format!("{grad:.3}"),
            format!("{thr:.0}"),
            format!("{:.2}x", thr / flat_adam),
        ]);
    }
    println!("{}", mt.render());
    println!(
        "sparse rows are the worst-case bound at the default sparsity (k=16 of\n\
         block=256 survivors, 16-bit index + 4-bit code each); actual wire size\n\
         is data-dependent and reported per run by the byte counters."
    );
    println!(
        "units: tok/s = whole-cluster training tokens per second; comm frac =\n\
         fraction of step wall time spent communicating; async gain = step-time\n\
         win from hiding the inter-island bf16 parameter gather behind the next\n\
         forward pass (train.sync_params = \"async\"); stale gain = win from\n\
         hiding the low-bit gradient exchange instead (train.grad_sync =\n\
         \"stale\", one-step-stale updates) — the two compose in the trainer.\n\
         wire B/param/step = bytes per parameter per optimizer step; local:H\n\
         pays the full 2.25 B/param exchange once per H steps.\n\
         island = 1 is the flat bucketed engine; the hierarchy compresses only the\n\
         inter-island hop, so its win grows with the NVLink/NIC bandwidth gap.\n\
         these are analytic predictions; to see the same schedule as measured\n\
         per-tier spans, run `loco train ... --trace out.json` and `loco trace\n\
         out.json` (topology/reduce_scatter + topology/broadcast rows)."
    );
    Ok(())
}

/// One row per tier of a recursive tree, plus the sync/async/stale
/// throughput of the whole schedule. Intra tiers are modeled on
/// NVLink/NVSwitch-class fabric, the outermost cut on the A800 IB
/// spine — the deployment the recursive engine is built for.
fn cmd_topology_tiers(gpus: usize, tiers: &[usize]) -> Result<()> {
    let model = loco::model::analytic_model("llama2-7b").context("analytic model")?;
    let mbs = 4096.0;
    let buckets = 8;
    let depth = tiers.len();
    let links: Vec<netsim::Interconnect> = (0..depth)
        .map(|l| if l + 1 == depth { netsim::A800_IB } else { netsim::NVLINK })
        .collect();
    // validate first (product must equal the GPU count) so a non-dividing
    // query errors out before any table is printed
    let (thr, frac) = analytic_throughput_tiered(
        model, netsim::A100, &links, gpus, tiers, mbs, 1.0, "loco", buckets,
    )?;
    let (thr_async, _) = analytic_throughput_tiered_async(
        model, netsim::A100, &links, gpus, tiers, mbs, 1.0, "loco", buckets,
    )?;
    let (thr_stale, _) = analytic_throughput_tiered_stale(
        model, netsim::A100, &links, gpus, tiers, mbs, 1.0, "loco",
    )?;
    let mut t = Table::new(
        &format!(
            "Recursive tier tree {tiers:?} over {gpus} GPUs \
             (llama2-7b, accum 1, analytic) — one row per tier"
        ),
        &["tier", "fan-out", "group size", "link", "schedule", "wire B/param"],
    );
    let mut stride = 1usize;
    for (l, &m) in tiers.iter().enumerate() {
        let outermost = l + 1 == depth;
        let per_param = if outermost {
            let mf = m as f64;
            gpus as f64 * netsim::wire_bytes_per_param("loco") * (mf - 1.0)
                / (mf * stride as f64)
        } else {
            let mf = m as f64;
            gpus as f64 * 6.0 * (mf - 1.0) / (mf * stride as f64)
        };
        t.row(vec![
            format!("{l}"),
            format!("{m}"),
            format!("{} GPUs", stride * m),
            links[l].name.to_string(),
            if outermost { "low-bit all-to-all + bf16 gather" } else { "fp32 reduce-scatter + bf16 broadcast" }
                .to_string(),
            format!("{per_param:.3}"),
        ]);
        stride *= m;
    }
    println!("{}", t.render());
    let dense_outer = outer_tier_grad_bytes_per_param(gpus, tiers, 4)?;
    println!(
        "outer-tier low-bit gradient bytes: {dense_outer:.3} B/param across the cluster per exchange",
    );
    // the sparse format's worst case at the defaults carries
    // (16+4)·16/256 = 1.25 bits per element vs the dense 4-bit wire
    println!(
        "outer-tier sparse gradient bytes (compress.method = \"sparse\", worst case \
         at k=16/block=256): {:.3} B/param",
        dense_outer * ((16.0 + 4.0) * 16.0 / 256.0) / 4.0
    );
    println!(
        "tok/s sync {thr:.0} | async {thr_async:.0} | stale {thr_stale:.0} | comm frac {:.1}%",
        100.0 * frac
    );
    println!(
        "units: wire B/param = bytes per parameter per optimizer step summed over\n\
         the whole cluster at that tier; intra tiers pay fp32+bf16 (6 B) on the\n\
         shrinking 1/M row, only the outermost cut carries the low-bit exchange."
    );
    Ok(())
}

/// Summarize a Chrome-trace file written by `loco train --trace`: one
/// row per span phase (category + name) with count, total and
/// p50/p95/p99 durations, heaviest phase first, plus the range of every
/// counter track. A malformed or truncated file is a hard error
/// (exit 1), never an empty table.
fn cmd_trace(args: &[String]) -> Result<()> {
    let [path] = args else {
        bail!("usage: loco trace FILE (a --trace output file)");
    };
    let path = PathBuf::from(path);
    let s = loco::trace::summarize(&path)?;
    println!(
        "{}: {} events across {} rank(s)",
        path.display(),
        s.events,
        s.ranks
    );
    let mut t = Table::new(
        "span phases — simulated time, heaviest first",
        &["category", "phase", "count", "total ms", "p50 us", "p95 us", "p99 us"],
    );
    for p in &s.spans {
        t.row(vec![
            p.cat.clone(),
            p.name.clone(),
            p.count.to_string(),
            format!("{:.3}", p.total_us / 1e3),
            format!("{:.1}", p.p50_us),
            format!("{:.1}", p.p95_us),
            format!("{:.1}", p.p99_us),
        ]);
    }
    println!("{}", t.render());
    if !s.counters.is_empty() {
        let mut c = Table::new(
            "counter tracks — per-step compression quality",
            &["track", "samples", "last", "min", "max"],
        );
        for k in &s.counters {
            c.row(vec![
                k.name.clone(),
                k.count.to_string(),
                format!("{:.4e}", k.last),
                format!("{:.4e}", k.min),
                format!("{:.4e}", k.max),
            ]);
        }
        println!("{}", c.render());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_quant_selftest() -> Result<()> {
    let art = loco::runtime::artifacts_dir();
    let block = 65536;
    let kernel = loco::runtime::LocoKernel::load(&art, block)
        .context("loading loco_step artifact (run `make artifacts`)")?;
    let mut rng = Rng::new(7);
    let mut g = vec![0.0f32; block];
    rng.fill_normal(&mut g, 0.1);
    let e: Vec<i8> = (0..block).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
    let (s, s_e, beta) = (16.0f32, 64.0f32, 0.125f32);

    let (q_xla, e_xla) = kernel.step(&g, &e, s, s_e, beta, false)?;
    let mut e_rust = e.clone();
    let mut q_rust = vec![0i8; block];
    let p = loco::quant::LocoParams { s, s_e, beta, bits: 4 };
    loco::quant::loco_step(&g, &mut e_rust, &mut q_rust, p, false);

    let q_diff = q_xla.iter().zip(&q_rust).filter(|(a, b)| a != b).count();
    let e_diff = e_xla.iter().zip(&e_rust).filter(|(a, b)| a != b).count();
    println!("loco_step parity over {block} elements: q mismatches={q_diff}, e mismatches={e_diff}");
    if q_diff + e_diff > 0 {
        bail!("Rust hot path disagrees with the L1 Pallas kernel");
    }
    println!("selftest OK — Rust hot path is bit-identical to the Pallas kernel");
    Ok(())
}

/// Without the PJRT backend the true L1 parity check cannot run; verify
/// the two Rust hot paths (scalar fused step and packed wire emitter)
/// against each other instead, which `tests/xla_parity.rs` pins to the
/// kernel whenever the `pjrt` feature is enabled.
#[cfg(not(feature = "pjrt"))]
fn cmd_quant_selftest() -> Result<()> {
    let block = 65536;
    let mut rng = Rng::new(7);
    let mut g = vec![0.0f32; block];
    rng.fill_normal(&mut g, 0.1);
    let e: Vec<i8> = (0..block).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
    let p = loco::quant::LocoParams { s: 16.0, s_e: 64.0, beta: 0.125, bits: 4 };

    let mut e_scalar = e.clone();
    let mut q_scalar = vec![0i8; block];
    loco::quant::loco_step(&g, &mut e_scalar, &mut q_scalar, p, false);
    let mut e_packed = e.clone();
    let mut packed = Vec::new();
    loco::quant::loco_step_packed(&g, &mut e_packed, &mut packed, p, false);

    let q_unpacked = loco::quant::unpack_nibbles(&packed, block);
    let q_diff = q_scalar.iter().zip(&q_unpacked).filter(|(a, b)| a != b).count();
    let e_diff = e_scalar.iter().zip(&e_packed).filter(|(a, b)| a != b).count();
    println!("loco_step scalar vs packed over {block} elements: q mismatches={q_diff}, e mismatches={e_diff}");
    if q_diff + e_diff > 0 {
        bail!("packed wire path disagrees with the scalar reference");
    }
    println!(
        "selftest OK — scalar and packed hot paths agree \
         (enable the `pjrt` feature + `make artifacts` for true L1 kernel parity)"
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("loco — LoCo: Low-Bit Communication Adaptor (reproduction)");
    let art = loco::runtime::artifacts_dir();
    println!("artifacts dir: {}", art.display());
    if art.is_dir() {
        let mut names: Vec<String> = std::fs::read_dir(&art)?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| !n.starts_with('.'))
            .collect();
        names.sort();
        for n in names {
            println!("  {n}");
        }
    } else {
        println!("  (missing — run `make artifacts`)");
    }
    println!(
        "trace: deterministic sim-time tracer (train --trace FILE writes \
         Perfetto/Chrome JSON; `loco trace FILE` summarizes; DESIGN.md §3.11)"
    );
    println!("subcommands: train, faults, table1, table8, throughput, topology, trace, quant-selftest, info");
    Ok(())
}

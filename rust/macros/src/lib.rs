//! Marker attributes for the `loco-verify` static-analysis pass.
//!
//! The attributes here are deliberately *inert at runtime*: they expand to
//! the unmodified item and exist only so that source-level tooling
//! (`cargo run -p loco-verify`) can find the marked regions by token scan.
//! Keeping the crate dependency-free (no `syn`/`quote`) means it builds
//! offline with nothing but the compiler-provided `proc_macro` API.

use proc_macro::TokenStream;

/// Marks a function as a steady-state-allocation-free hot kernel.
///
/// `loco-verify` denies allocation calls (`Vec::new`, `Box::new`,
/// `to_vec`, `collect::<Vec<_>>`, `format!`, `vec!`, `String::from`, …)
/// inside the body of any function carrying this attribute. The runtime
/// counterpart is the counting global allocator in `tests/scaling.rs`;
/// this marker turns that spot check into a tree-wide gate.
///
/// The attribute itself is a no-op passthrough: it returns the item
/// unchanged, so marked kernels compile identically with or without the
/// verify pass installed.
#[proc_macro_attribute]
pub fn hot_kernel(_attr: TokenStream, item: TokenStream) -> TokenStream {
    item
}

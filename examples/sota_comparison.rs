//! Fig. 2-style comparison: loss curves of low-bit communication methods
//! against the 16-bit baseline on a from-scratch pre-train (synthetic
//! corpus substitution — DESIGN.md). Writes one CSV per method to runs/.
//!
//!     cargo run --release --example sota_comparison -- [--steps N] [--model tiny]

use loco::compress::{CompressorConfig, Method};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::report::Table;
use loco::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut steps: u64 = 200;
    let mut model = "tiny".to_string();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--steps" => {
                i += 1;
                steps = argv[i].parse()?;
            }
            "--model" => {
                i += 1;
                model = argv[i].clone();
            }
            other => anyhow::bail!("unknown arg {other}"),
        }
        i += 1;
    }

    let methods: Vec<(&str, Method, u32)> = vec![
        ("adam-16bit", Method::Bf16, 16),
        ("loco-4bit", Method::Loco, 4),
        ("loco-1bit", Method::Loco, 1),
        ("onebit-adam", Method::OneBit, 1),
        ("zeropp-4bit", Method::Zeropp, 4),
        ("loco-zeropp", Method::LocoZeropp, 4),
    ];

    let mut table = Table::new(
        &format!("Fig. 2 analogue — {model}, {steps} steps, 4 nodes"),
        &["method", "bits", "final train", "final val", "wire bytes"],
    );
    for (name, method, bits) in methods {
        let mut cfg = TrainConfig::new(&model);
        cfg.nodes = 4;
        cfg.steps = steps;
        cfg.eval_every = (steps / 5).max(1);
        cfg.log_every = (steps / 50).max(1);
        cfg.optim = OptimConfig { kind: OptimizerKind::Adam, ..Default::default() };
        cfg.lr = LrSchedule { base: 3e-3, warmup: steps / 10 + 5, total: steps, min_ratio: 0.1 };
        cfg.compressor = CompressorConfig {
            bits,
            s: (1u32 << 17) as f32,
            ..CompressorConfig::with_method(method)
        };
        let m = Trainer::new(cfg).run()?.metrics;
        let csv = std::path::PathBuf::from(format!("runs/fig2_{name}.csv"));
        m.write_csv(&csv)?;
        table.row(vec![
            name.into(),
            bits.to_string(),
            format!("{:.4}", m.train_loss.tail_mean(5)),
            format!("{:.4}", m.val_loss.last().unwrap_or(f64::NAN)),
            loco::util::human_bytes(m.comm_bytes),
        ]);
        println!("{name}: done ({:.1}s)", m.elapsed);
    }
    println!("\n{}", table.render());
    println!("per-step curves in runs/fig2_*.csv");
    Ok(())
}

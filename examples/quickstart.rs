//! Quickstart: train a tiny transformer with LoCo-Adam on 4 in-process
//! nodes and compare the wire traffic against 16-bit Adam.
//!
//!     make artifacts && cargo run --release --example quickstart

use loco::compress::{CompressorConfig, Method};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::train::{TrainConfig, Trainer};
use loco::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::new("tiny");
    cfg.nodes = 4;
    cfg.steps = 60;
    cfg.eval_every = 20;
    cfg.optim = OptimConfig { kind: OptimizerKind::Adam, ..Default::default() };
    cfg.lr = LrSchedule { base: 3e-3, warmup: 10, total: 60, min_ratio: 0.2 };

    println!("== LoCo quickstart: tiny GPT, 4 nodes, Zero-2 sharding ==\n");
    let mut rows = Vec::new();
    for method in [Method::Bf16, Method::Loco] {
        let mut c = cfg.clone();
        c.compressor = CompressorConfig {
            s: (1u32 << 17) as f32,
            ..CompressorConfig::with_method(method)
        };
        let r = Trainer::new(c).run()?;
        let m = r.metrics;
        println!(
            "{:6}  train loss {:.4}  val loss {:.4}  grad+param wire {:>10}  state {:>9}",
            method.name(),
            m.train_loss.tail_mean(3),
            m.val_loss.last().unwrap_or(f64::NAN),
            human_bytes(m.comm_bytes),
            human_bytes(m.compressor_state_bytes as u64),
        );
        rows.push((method, m));
    }
    let ratio = rows[0].1.comm_bytes as f64 / rows[1].1.comm_bytes as f64;
    println!(
        "\nLoCo moved {ratio:.2}x fewer bytes than 16-bit Adam at matching loss \
         (4-bit gradients + int8 error store, Algorithm 1)."
    );
    Ok(())
}

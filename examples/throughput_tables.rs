//! Print the analytic reproductions of the paper's speed & memory tables
//! (Tables 1, 7/11, 8, 10/12) — no training, instant.
//!
//!     cargo run --release --example throughput_tables

use loco::model::analytic_model;
use loco::netsim::throughput::{
    analytic_throughput, paper_speedup, predict_speedup, ACCUMS, PAPER_BASELINES,
};
use loco::netsim::{self, A100, A100_ROCE, A800_IB};
use loco::report::Table;

fn main() {
    // Table 1
    println!("{}", netsim::table1::render(7e9, 64.0, 25e9, 4.0).render());

    // Tables 7/11/12 (fit mode)
    let mut t = Table::new(
        "Tables 7/11/12 — LoCo speedup over 16-bit Adam (fitted model vs paper)",
        &["model", "cluster", "gpus", "accum", "paper", "model", "err(pp)"],
    );
    let mut errs = Vec::new();
    for row in PAPER_BASELINES {
        for (i, &a) in ACCUMS.iter().enumerate() {
            let paper = paper_speedup(row, i) - 1.0;
            let pred = predict_speedup(row, a, "loco") - 1.0;
            errs.push((pred - paper).abs());
            t.row(vec![
                row.model.into(),
                row.cluster.into(),
                row.gpus.to_string(),
                format!("{a:.0}"),
                format!("{:.2}%", 100.0 * paper),
                format!("{:.2}%", 100.0 * pred),
                format!("{:+.2}", 100.0 * (pred - paper)),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "mean |model - paper| = {:.2}pp over {} cells\n",
        100.0 * errs.iter().sum::<f64>() / errs.len() as f64,
        errs.len()
    );

    // Table 8
    let mut t8 = Table::new(
        "Table 8 — peak memory (GB)",
        &["model", "framework", "Adam (paper)", "LoCo (paper)", "LoCo (model)", "err"],
    );
    for row in netsim::memory::PAPER_MEMORY {
        let pred = netsim::memory::predict_loco_peak(row.framework, row.params, row.adam_gb);
        t8.row(vec![
            row.model.into(),
            row.framework.into(),
            format!("{:.1}", row.adam_gb),
            format!("{:.1}", row.loco_gb),
            format!("{:.1}", pred),
            format!("{:+.1}%", 100.0 * (pred - row.loco_gb) / row.loco_gb),
        ]);
    }
    println!("{}", t8.render());

    // First-principles sanity (analytic mode)
    let mut ta = Table::new(
        "Analytic mode (first principles, A800-IB, accum 1, mbs 4096 tokens/GPU)",
        &["model", "gpus", "adam tok/s", "loco tok/s", "speedup", "comm frac (adam)"],
    );
    for name in ["llama2-7b", "llama2-13b", "llama2-70b", "mixtral-8x7b"] {
        let m = analytic_model(name).unwrap();
        for gpus in [32usize, 64, 128] {
            let (adam, frac) = analytic_throughput(m, A100, A800_IB, gpus, 4096.0, 1.0, "adam");
            let (lo, _) = analytic_throughput(m, A100, A800_IB, gpus, 4096.0, 1.0, "loco");
            ta.row(vec![
                name.into(),
                gpus.to_string(),
                format!("{adam:.0}"),
                format!("{lo:.0}"),
                format!("{:.2}%", 100.0 * (lo / adam - 1.0)),
                format!("{:.2}", frac),
            ]);
        }
    }
    println!("{}", ta.render());
    let _ = A100_ROCE;
}

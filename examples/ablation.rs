//! Table 9-style ablation of LoCo's components on a fine-tuning run:
//! error feedback, moving average, error compression, reset frequency.
//!
//!     cargo run --release --example ablation -- [--steps N]

use loco::compress::{CompressorConfig, Method};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::report::Table;
use loco::train::{TrainConfig, Trainer};

fn variant(name: &'static str, f: impl Fn(&mut CompressorConfig)) -> (&'static str, CompressorConfig) {
    let mut c = CompressorConfig {
        s: (1u32 << 17) as f32,
        ..CompressorConfig::with_method(Method::Loco)
    };
    f(&mut c);
    (name, c)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = if argv.len() == 2 && argv[0] == "--steps" {
        argv[1].parse()?
    } else {
        150
    };

    // pretrain once, then fine-tune under each ablation (matching the
    // paper's fine-tune protocol for Table 9)
    println!("pretraining base checkpoint ({steps} steps)...");
    let mut pre = TrainConfig::new("tiny");
    pre.nodes = 4;
    pre.steps = steps;
    pre.optim = OptimConfig { kind: OptimizerKind::Adam, ..Default::default() };
    pre.lr = LrSchedule { base: 3e-3, warmup: 10, total: steps, min_ratio: 0.1 };
    pre.compressor.method = Method::Bf16;
    let ckpt = Trainer::new(pre).run()?.final_params;

    let variants = vec![
        variant("LoCo1 (no error feedback)", |c| c.no_error_feedback = true),
        variant("LoCo2 (EF, no avg, no reset)", |c| {
            c.no_moving_average = true;
            c.reset_interval = 0;
        }),
        variant("LoCo3 (EF+avg, no reset)", |c| c.reset_interval = 0),
        variant("LoCo4 (no error compression)", |c| {
            c.error_bits = 32;
            c.reset_interval = 512;
        }),
        variant("LoCo5 (full, Tc=512)", |c| c.reset_interval = 512),
        variant("LoCo6 (full, Tc=128)", |c| c.reset_interval = 128),
    ];

    let mut table = Table::new(
        &format!("Table 9 analogue — fine-tune ablation, {steps} steps"),
        &["variant", "final train", "final val", "enc state bytes"],
    );
    for (name, comp) in variants {
        let mut cfg = TrainConfig::new("tiny");
        cfg.nodes = 4;
        cfg.steps = steps;
        cfg.eval_every = (steps / 3).max(1);
        cfg.optim = OptimConfig { kind: OptimizerKind::Adam, ..Default::default() };
        cfg.lr = LrSchedule { base: 1e-3, warmup: 5, total: steps, min_ratio: 0.2 };
        cfg.compressor = comp;
        cfg.init_params = Some(ckpt.clone());
        cfg.corpus_noise = Some(0.1); // shifted distribution = "fine-tune task"
        let m = Trainer::new(cfg).run()?.metrics;
        table.row(vec![
            name.into(),
            format!("{:.4}", m.train_loss.tail_mean(5)),
            format!("{:.4}", m.val_loss.last().unwrap_or(f64::NAN)),
            m.compressor_state_bytes.to_string(),
        ]);
        println!("{name}: done");
    }
    println!("\n{}", table.render());
    Ok(())
}

//! End-to-end validation driver (DESIGN.md §5): pre-train a transformer LM
//! from scratch on the synthetic corpus across N in-process nodes with
//! LoCo 4-bit communication, log the loss curve + throughput + wire bytes,
//! and (optionally) run the 16-bit Adam control for comparison.
//!
//!     # small default (fits in seconds)
//!     cargo run --release --example e2e_pretrain
//!     # the full run recorded in EXPERIMENTS.md (~20M params):
//!     make artifacts-big && cargo run --release --example e2e_pretrain -- \
//!         --model base20m --steps 300 --nodes 4 --compare --csv runs/e2e.csv

use std::path::PathBuf;

use loco::compress::{CompressorConfig, Method};
use loco::optim::{LrSchedule, OptimConfig, OptimizerKind};
use loco::train::{TrainConfig, Trainer};
use loco::util::human_bytes;

struct Args {
    model: String,
    steps: u64,
    nodes: usize,
    accum: usize,
    method: Method,
    compare: bool,
    csv: Option<PathBuf>,
    lr: f32,
}

fn parse_args() -> Args {
    let mut a = Args {
        model: "small".into(),
        steps: 200,
        nodes: 4,
        accum: 1,
        method: Method::Loco,
        compare: false,
        csv: Some(PathBuf::from("runs/e2e.csv")),
        lr: 1e-3,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--model" => {
                i += 1;
                a.model = argv[i].clone();
            }
            "--steps" => {
                i += 1;
                a.steps = argv[i].parse().expect("steps");
            }
            "--nodes" => {
                i += 1;
                a.nodes = argv[i].parse().expect("nodes");
            }
            "--accum" => {
                i += 1;
                a.accum = argv[i].parse().expect("accum");
            }
            "--lr" => {
                i += 1;
                a.lr = argv[i].parse().expect("lr");
            }
            "--method" => {
                i += 1;
                a.method = Method::parse(&argv[i]).expect("method");
            }
            "--compare" => a.compare = true,
            "--csv" => {
                i += 1;
                a.csv = Some(PathBuf::from(&argv[i]));
            }
            other => panic!("unknown arg {other}"),
        }
        i += 1;
    }
    a
}

fn run_one(a: &Args, method: Method) -> anyhow::Result<loco::metrics::RunMetrics> {
    let mut cfg = TrainConfig::new(&a.model);
    cfg.nodes = a.nodes;
    cfg.steps = a.steps;
    cfg.accum = a.accum;
    cfg.eval_every = (a.steps / 6).max(1);
    cfg.log_every = (a.steps / 50).max(1);
    cfg.optim = OptimConfig { kind: OptimizerKind::Adam, ..Default::default() };
    cfg.lr = LrSchedule { base: a.lr, warmup: a.steps / 20 + 5, total: a.steps, min_ratio: 0.1 };
    cfg.compressor = CompressorConfig {
        s: (1u32 << 17) as f32,
        ..CompressorConfig::with_method(method)
    };
    Ok(Trainer::new(cfg).run()?.metrics)
}

fn main() -> anyhow::Result<()> {
    let a = parse_args();
    println!(
        "== e2e pretrain: model={} nodes={} steps={} accum={} method={} ==",
        a.model,
        a.nodes,
        a.steps,
        a.accum,
        a.method.name()
    );

    let m = run_one(&a, a.method)?;
    println!("loss curve ({} points):", m.train_loss.points.len());
    for &(step, loss) in &m.train_loss.points {
        println!("  step {step:>5}  train {loss:.4}");
    }
    for &(step, loss) in &m.val_loss.points {
        println!("  step {step:>5}  VAL   {loss:.4}");
    }
    println!(
        "\n{}: {:.0} tokens/s | wall {:.1}s | wire {} ({:.2}x vs fp32) | compressor state {}",
        a.method.name(),
        m.tokens_per_sec,
        m.elapsed,
        human_bytes(m.comm_bytes),
        m.compression_ratio(),
        human_bytes(m.compressor_state_bytes as u64),
    );
    if let Some(csv) = &a.csv {
        m.write_csv(csv)?;
        println!("wrote {}", csv.display());
    }

    if a.compare {
        println!("\nrunning 16-bit Adam control...");
        let c = run_one(&a, Method::Bf16)?;
        println!(
            "control bf16: final train {:.4} (LoCo {:.4}), val {:.4} (LoCo {:.4}), wire {} (LoCo {})",
            c.train_loss.tail_mean(5),
            m.train_loss.tail_mean(5),
            c.val_loss.last().unwrap_or(f64::NAN),
            m.val_loss.last().unwrap_or(f64::NAN),
            human_bytes(c.comm_bytes),
            human_bytes(m.comm_bytes),
        );
        if let Some(csv) = &a.csv {
            let ctrl = csv.with_extension("control.csv");
            c.write_csv(&ctrl)?;
            println!("wrote {}", ctrl.display());
        }
    }
    Ok(())
}

"""L2: JAX transformer LM (dense + MoE) forward/backward for AOT lowering.

The model is a LLAMA-style decoder (RMSNorm, SwiGLU MLP, causal attention
via the L1 Pallas kernel) with learned positional embeddings, plus a
Mixtral-style top-2 routed MoE variant (dense expert compute with gating
masks — exact at the tiny scales we train, and it lowers to static HLO).

Parameters are an ordered *list* of fp32 arrays. The same order is written
to the artifact manifest so the Rust coordinator can allocate, initialize,
shard, and feed them positionally. The lowered `train` graph maps

    (p_0 ... p_{P-1}, tokens[i32 B,T]) -> (loss, g_0 ... g_{P-1})

and the `eval` graph maps (params, tokens) -> (loss,).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import causal_attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int
    n_experts: int = 0   # 0 => dense MLP
    top_k: int = 2

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# Model zoo. `tiny`/`small`/`moe_tiny` drive the convergence experiments
# (Fig. 2, Tables 3/4/5/9 analogues); `base20m`/`base100m` drive the
# end-to-end example. 7B-70B configs exist only analytically in rust netsim.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=64, n_layers=2,
                        n_heads=4, d_ff=192, seq=64, batch=8),
    "small": ModelConfig("small", vocab=2048, d_model=128, n_layers=4,
                         n_heads=4, d_ff=384, seq=128, batch=8),
    "moe_tiny": ModelConfig("moe_tiny", vocab=512, d_model=64, n_layers=2,
                            n_heads=4, d_ff=128, seq=64, batch=8,
                            n_experts=8, top_k=2),
    "base20m": ModelConfig("base20m", vocab=4096, d_model=384, n_layers=8,
                           n_heads=6, d_ff=1024, seq=256, batch=4),
    "base100m": ModelConfig("base100m", vocab=8192, d_model=768, n_layers=12,
                            n_heads=12, d_ff=2048, seq=256, batch=2),
}


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the contract shared with Rust."""
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (t, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2", (d,)),
        ]
        if cfg.is_moe:
            e = cfg.n_experts
            spec += [
                (p + "router", (d, e)),
                (p + "w_gate", (e, d, f)),
                (p + "w_up", (e, d, f)),
                (p + "w_down", (e, f, d)),
            ]
        else:
            spec += [
                (p + "w_gate", (d, f)),
                (p + "w_up", (d, f)),
                (p + "w_down", (f, d)),
            ]
    spec += [("ln_f", (d,)), ("head", (d, v))]
    return spec


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def init_params(cfg: ModelConfig, key) -> List[jnp.ndarray]:
    """Scaled-normal init; Rust re-implements this bit-exactly is NOT
    required — rust does its own init and both sides only exchange HLO."""
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if "emb" in name else 1.0 / jnp.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def _rmsnorm(x, w, eps=1e-5):
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / rms * w


def _dense_mlp(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _moe_mlp(x, router, w_gate, w_up, w_down, top_k: int):
    """Top-k routed SwiGLU experts, computed densely with a gating mask.

    x: [N, d]; router: [d, E]; experts stacked on axis 0.
    Exact top-k gating (renormalized softmax over selected experts), as in
    Mixtral; dense compute keeps the lowered HLO static.
    """
    logits = x @ router                              # [N, E]
    e = logits.shape[-1]
    # top-k via iterated argmax: jax.lax.top_k lowers to a sort op with a
    # `largest=` attribute that the xla_extension 0.5.1 HLO parser rejects;
    # argmax lowers to a plain reduce and round-trips cleanly.
    mask = jnp.zeros_like(logits)
    masked_logits = logits
    for _ in range(top_k):
        idx = jnp.argmax(masked_logits, axis=-1)
        hot = jax.nn.one_hot(idx, e, dtype=x.dtype)
        mask = mask + hot
        masked_logits = masked_logits - hot * 1e30
    masked = jnp.where(mask > 0, logits, -1e30)
    gates = jax.nn.softmax(masked, axis=-1) * mask   # renormalized, [N, E]
    # [E, N, f] = silu(x @ w_gate[e]) * (x @ w_up[e])
    hidden = jax.nn.silu(jnp.einsum("nd,edf->enf", x, w_gate))
    hidden = hidden * jnp.einsum("nd,edf->enf", x, w_up)
    out = jnp.einsum("enf,efd->end", hidden, w_down)  # [E, N, d]
    return jnp.einsum("ne,end->nd", gates, out)


def forward_loss(params: List[jnp.ndarray], tokens: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    """Mean next-token cross-entropy over a [B, T] int32 batch."""
    it = iter(params)
    nxt = lambda: next(it)
    b, t = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim

    tok_emb, pos_emb = nxt(), nxt()
    x = tok_emb[tokens] + pos_emb[None, :t, :]

    for _ in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2 = nxt(), nxt(), nxt(), nxt(), nxt(), nxt()
        y = _rmsnorm(x, ln1)
        q = (y @ wq).reshape(b, t, h, dh)
        k = (y @ wk).reshape(b, t, h, dh)
        v = (y @ wv).reshape(b, t, h, dh)
        attn = causal_attention(q, k, v).reshape(b, t, d)
        x = x + attn @ wo
        y = _rmsnorm(x, ln2)
        if cfg.is_moe:
            router, w_gate, w_up, w_down = nxt(), nxt(), nxt(), nxt()
            flat = y.reshape(b * t, d)
            x = x + _moe_mlp(flat, router, w_gate, w_up, w_down,
                             cfg.top_k).reshape(b, t, d)
        else:
            w_gate, w_up, w_down = nxt(), nxt(), nxt()
            x = x + _dense_mlp(y, w_gate, w_up, w_down)

    ln_f, head = nxt(), nxt()
    x = _rmsnorm(x, ln_f)
    logits = x[:, :-1, :] @ head                      # [B, T-1, V]
    targets = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_fn(cfg: ModelConfig):
    """(params..., tokens) -> (loss, grads...) for jit/lowering."""
    def train_fn(*args):
        params = list(args[:-1])
        tokens = args[-1]
        loss, grads = jax.value_and_grad(
            lambda p: forward_loss(p, tokens, cfg))(params)
        return tuple([loss] + list(grads))
    return train_fn


def make_eval_fn(cfg: ModelConfig):
    def eval_fn(*args):
        params = list(args[:-1])
        tokens = args[-1]
        return (forward_loss(params, tokens, cfg),)
    return eval_fn

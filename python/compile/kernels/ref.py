"""Pure-jnp oracles for the Pallas kernels (L1 correctness baseline).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest checks the Pallas kernels
(interpret=True) against these oracles over shape/dtype/parameter sweeps,
and the Rust hot path is checked against the same semantics through the
AOT-compiled artifacts.

Numerical contract shared with the Rust implementation (rust/src/quant):
  * rounding is round-half-to-even (jnp.round / f32::round_ties_even),
  * int4 range is [-8, 7], int8 range is [-128, 127],
  * the error moving average is computed from the *dequantized* stored
    error (the 8-bit e_k), matching the memory-efficient variant the paper
    deploys (Sec. 3.2: "LoCo maintains only a local average of the
    compressed errors ... stored in 8-bit format").
"""

from __future__ import annotations

import jax.numpy as jnp

INT4_MIN, INT4_MAX = -8.0, 7.0
INT8_MIN, INT8_MAX = -128.0, 127.0


def quantize(x: jnp.ndarray, scale: float, bits: int) -> jnp.ndarray:
    """compressor(h; s, p) = round_p-bit(h * s)  (Eqn. 1), as int8 values."""
    lo, hi = (INT4_MIN, INT4_MAX) if bits == 4 else (INT8_MIN, INT8_MAX)
    return jnp.clip(jnp.round(x * scale), lo, hi).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    """decompressor(q; s) = float(q) / s  (Eqn. 1)."""
    return q.astype(jnp.float32) / scale


def loco_step_ref(g, e_q, s, s_e, beta, reset):
    """One LoCo compensate -> quantize -> error-update step (Algorithm 1).

    Args:
      g:     fp32 local gradient shard.
      e_q:   int8 stored compensation error (quantized with scale s_e).
      s:     gradient quantization scale (4-bit).
      s_e:   error quantization scale (8-bit).
      beta:  moving-average coefficient (Eqn. 5).
      reset: bool — if True, the returned stored error is zeroed (Eqn. 7).

    Returns:
      q4:      int8 array holding the 4-bit codes in [-8, 7] (wire format;
               the Rust side nibble-packs two codes per byte).
      e_new_q: int8 updated stored error.
    """
    e_f = dequantize(e_q, s_e)                      # Eqn. (2) decompress
    h = g + e_f                                     # Eqn. (2) compensate
    q4 = quantize(h, s, bits=4)                     # Eqn. (3)
    d = dequantize(q4, s)                           # Alg. 1 line 7
    e_tilde = (1.0 - beta) * e_f + beta * (h - d)   # Eqn. (5)
    e_new_q = jnp.where(
        reset,
        jnp.zeros_like(e_q),
        quantize(e_tilde, s_e, bits=8),             # Eqn. (7)
    )
    return q4, e_new_q


def attention_ref(q, k, v, causal: bool = True):
    """Dense causal attention oracle: softmax(q k^T / sqrt(dh)) v.

    Shapes: q,k,v = [T, H, Dh] (single sequence); returns [T, H, Dh].
    """
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("qhd,khd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, v)

"""L1 Pallas kernel: fused LoCo compensate -> 4-bit quantize -> error update.

This is the paper's compute hot-spot: Algorithm 1 steps 1-2 applied to a
gradient shard right before communication. One fused pass over the shard

    e_f   = deq(e_q; s_e)                 # stored int8 error -> fp32
    h     = g + e_f                       # compensation (Eqn. 2)
    q4    = clip(round(h * s), -8, 7)     # 4-bit code (Eqn. 3)
    e~    = (1-b) e_f + b (h - q4/s)      # moving average  (Eqn. 5)
    e_q'  = reset ? 0 : clip(round(e~ * s_e), -128, 127)   # (Eqn. 7)

reads 5 bytes/element (fp32 grad + int8 error) and writes 2 bytes/element
(two int8 streams; the wire format packs q4 to 4 bits afterwards) — i.e. it
is strictly bandwidth-bound with zero MXU work.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA original is an
elementwise grid-stride kernel; here the HBM<->VMEM schedule is expressed
with a 1-D grid over BLOCK-sized tiles via BlockSpec, scalars riding along
as (1,)-blocks mapped to the same origin for every tile. interpret=True is
mandatory on this image: real TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; with interpret=True the kernel lowers to
plain HLO and runs on any backend with identical numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 64Ki elements. VMEM estimate per tile (fp32 in, int8 err in,
# 2x int8 out, fp32 intermediates): ~ 64Ki * (4+1+2+8) B = 960 KiB, double
# buffered < 2 MiB — comfortably inside a 16 MiB VMEM budget.
BLOCK = 65536


def _loco_kernel(g_ref, e_ref, s_ref, se_ref, beta_ref, reset_ref,
                 q_ref, enew_ref):
    """Elementwise fused LoCo step over one BLOCK tile."""
    s = s_ref[0]
    se = se_ref[0]
    beta = beta_ref[0]
    reset = reset_ref[0]

    g = g_ref[...]
    e_f = e_ref[...].astype(jnp.float32) / se
    h = g + e_f
    q = jnp.clip(jnp.round(h * s), -8.0, 7.0)
    d = q / s
    e_tilde = (1.0 - beta) * e_f + beta * (h - d)
    e_new = jnp.clip(jnp.round(e_tilde * se), -128.0, 127.0)
    e_new = jnp.where(reset > 0, jnp.zeros_like(e_new), e_new)

    q_ref[...] = q.astype(jnp.int8)
    enew_ref[...] = e_new.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block",))
def loco_step(g, e_q, s, s_e, beta, reset, *, block: int = BLOCK):
    """Fused LoCo step over a flat fp32 shard whose length % block == 0.

    Args:
      g:     fp32[n] local gradient shard (n divisible by `block`).
      e_q:   int8[n] stored compensation error.
      s, s_e, beta: fp32 scalars (passed as shape-(1,) arrays or scalars).
      reset: int32 scalar/1-vector; nonzero zeroes the stored error.

    Returns (q4 int8[n] in [-8,7], e_new int8[n]).
    """
    n = g.shape[0]
    assert n % block == 0, f"shard length {n} not a multiple of {block}"
    grid = (n // block,)

    as1 = lambda x, dt: jnp.asarray(x, dt).reshape((1,))
    s = as1(s, jnp.float32)
    s_e = as1(s_e, jnp.float32)
    beta = as1(beta, jnp.float32)
    reset = as1(reset, jnp.int32)

    data_spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    return pl.pallas_call(
        _loco_kernel,
        grid=grid,
        in_specs=[data_spec, data_spec,
                  scalar_spec, scalar_spec, scalar_spec, scalar_spec],
        out_specs=[data_spec, data_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.int8),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(g, e_q, s, s_e, beta, reset)


def vmem_bytes(block: int = BLOCK) -> int:
    """Static VMEM footprint estimate for one tile (for DESIGN §Perf)."""
    per_elem = 4 + 1 + 1 + 1 + 4 + 4  # g, e_q, q4, e_new, h, e_tilde
    return block * per_elem

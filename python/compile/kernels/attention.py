"""L1 Pallas kernel: blocked causal attention used inside the L2 model.

Forward is a Pallas kernel (one grid cell per (batch, head); the whole
[T, Dh] tile for that head lives in VMEM — at the sequence lengths this
repo trains (T <= 512, Dh <= 64) the T x T logits tile fits comfortably:
512*512*4 B = 1 MiB). Backward is a dense jnp recomputation registered via
jax.custom_vjp, the standard pattern for differentiating through Pallas
kernels (pallas_call has no automatic transpose rule).

TPU adaptation: the CUDA flash-attention original streams K/V tiles through
shared memory per threadblock; on TPU the analogous schedule is a BlockSpec
that pins one (batch, head) Q/K/V tile in VMEM and lets the MXU consume the
[T, Dh] x [Dh, T] matmul directly in bf16/f32. interpret=True lowers it to
plain HLO (mandatory on CPU PJRT — see loco_quant.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    """Causal attention for a single (batch, head) tile: [T, Dh]."""
    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    t, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.dot(q, k.T) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    logits = jnp.where(cols <= rows, logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[0, :, 0, :] = jnp.dot(p, v)


def _attn_fwd_pallas(q, k, v):
    b, t, h, dh = q.shape
    spec = pl.BlockSpec((1, t, 1, dh), lambda i, j: (i, 0, j, 0))
    return pl.pallas_call(
        _attn_fwd_kernel,
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def causal_attention(q, k, v):
    """softmax(q k^T / sqrt(Dh) + causal mask) v over [B, T, H, Dh]."""
    return _attn_fwd_pallas(q, k, v)


def _fwd(q, k, v):
    return _attn_fwd_pallas(q, k, v), (q, k, v)


def _bwd(res, do):
    q, k, v = res
    t = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask[None, None, :, :], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=-1, keepdims=True)           # [B,H,Q,K]
    dv = jnp.einsum("bhqk,bqhd->bkhd", p, do)
    dp = jnp.einsum("bqhd,bkhd->bhqk", do, v)
    dlogit = p * (dp - (dp * p).sum(axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bkhd->bqhd", dlogit, k) * scale
    dk = jnp.einsum("bhqk,bqhd->bkhd", dlogit, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_fwd, _bwd)

"""AOT build: lower L2/L1 JAX graphs to HLO *text* artifacts for Rust.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the XLA
the published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts per model config:
  artifacts/model_<cfg>_train.hlo.txt  (params..., tokens) -> (loss, grads...)
  artifacts/model_<cfg>_eval.hlo.txt   (params..., tokens) -> (loss,)
  artifacts/model_<cfg>.manifest       ordered param table for Rust

Plus the standalone L1 kernel (used by Rust for parity checks against its
native hot path and as an optional XLA-executed quantization route):
  artifacts/loco_step_<block>.hlo.txt  (g, e, s, s_e, beta, reset) -> (q4, e')

Usage:  cd python && python -m compile.aot [--configs tiny,small,moe_tiny]
                                           [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import CONFIGS, ModelConfig, make_eval_fn, make_train_fn, \
    param_count, param_spec
from compile.kernels.loco_quant import loco_step

DEFAULT_CONFIGS = "tiny,small,moe_tiny"
DEFAULT_KERNEL_BLOCKS = (65536,)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_if_changed(path: str, text: str) -> bool:
    if os.path.exists(path):
        with open(path) as f:
            if f.read() == text:
                return False
    with open(path, "w") as f:
        f.write(text)
    return True


def manifest_text(cfg: ModelConfig) -> str:
    lines = [
        "# loco model manifest v1",
        f"config {cfg.name}",
        f"vocab {cfg.vocab}",
        f"batch {cfg.batch}",
        f"seq {cfg.seq}",
        f"n_layers {cfg.n_layers}",
        f"d_model {cfg.d_model}",
        f"n_heads {cfg.n_heads}",
        f"d_ff {cfg.d_ff}",
        f"n_experts {cfg.n_experts}",
        f"top_k {cfg.top_k}",
        f"param_count {param_count(cfg)}",
        f"params {len(param_spec(cfg))}",
    ]
    for name, shape in param_spec(cfg):
        lines.append(f"{name} f32 {','.join(str(s) for s in shape)}")
    return "\n".join(lines) + "\n"


def build_model(cfg: ModelConfig, out_dir: str) -> None:
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_spec(cfg)]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    for kind, fn in (("train", make_train_fn(cfg)), ("eval", make_eval_fn(cfg))):
        lowered = jax.jit(fn).lower(*specs, tok)
        path = os.path.join(out_dir, f"model_{cfg.name}_{kind}.hlo.txt")
        changed = write_if_changed(path, to_hlo_text(lowered))
        print(f"  {path} {'(written)' if changed else '(up-to-date)'}")

    mpath = os.path.join(out_dir, f"model_{cfg.name}.manifest")
    write_if_changed(mpath, manifest_text(cfg))
    print(f"  {mpath}")


def build_loco_kernel(block: int, out_dir: str) -> None:
    g = jax.ShapeDtypeStruct((block,), jnp.float32)
    e = jax.ShapeDtypeStruct((block,), jnp.int8)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(g, e, s, s_e, beta, reset):
        return loco_step(g, e, s, s_e, beta, reset, block=block)

    lowered = jax.jit(fn).lower(g, e, scalar_f, scalar_f, scalar_f, scalar_i)
    path = os.path.join(out_dir, f"loco_step_{block}.hlo.txt")
    changed = write_if_changed(path, to_hlo_text(lowered))
    print(f"  {path} {'(written)' if changed else '(up-to-date)'}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default=DEFAULT_CONFIGS,
                    help=f"comma list of {sorted(CONFIGS)}")
    ap.add_argument("--kernel-blocks", default=",".join(
        str(b) for b in DEFAULT_KERNEL_BLOCKS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for name in filter(None, args.configs.split(",")):
        cfg = CONFIGS[name]
        print(f"config {name}: {param_count(cfg):,} params")
        build_model(cfg, args.out_dir)
    for block in filter(None, args.kernel_blocks.split(",")):
        build_loco_kernel(int(block), args.out_dir)
    # stamp file lets `make` treat the whole artifact set as one target
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()

"""AOT build contract: manifests match param_spec, HLO text is emitted in
the parser-compatible dialect, rebuilds are idempotent."""

import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import CONFIGS, param_count, param_spec


def test_manifest_matches_param_spec():
    for name, cfg in CONFIGS.items():
        text = aot.manifest_text(cfg)
        lines = [l for l in text.splitlines() if l and not l.startswith("#")]
        kv = dict(l.split(" ", 1) for l in lines if len(l.split()) == 2)
        assert int(kv["param_count"]) == param_count(cfg)
        assert int(kv["params"]) == len(param_spec(cfg))
        # tensor lines in order
        tensor_lines = lines[12:]  # 12 header key-value lines
        assert len(tensor_lines) == len(param_spec(cfg))
        for line, (pname, shape) in zip(tensor_lines, param_spec(cfg)):
            toks = line.split()
            assert toks[0] == pname
            assert toks[1] == "f32"
            assert toks[2] == ",".join(str(s) for s in shape)


def test_write_if_changed_is_idempotent():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.txt")
        assert aot.write_if_changed(p, "hello")
        assert not aot.write_if_changed(p, "hello")
        assert aot.write_if_changed(p, "world")


def test_hlo_text_has_no_unparseable_attrs():
    """xla_extension 0.5.1's HLO parser rejects some modern attributes
    (e.g. sort's `largest=`); the emitted text must avoid them."""
    cfg = CONFIGS["moe_tiny"]
    from compile.model import make_eval_fn
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_spec(cfg)]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    lowered = jax.jit(make_eval_fn(cfg)).lower(*specs, tok)
    text = aot.to_hlo_text(lowered)
    assert "largest=" not in text, "top_k sort attr breaks the 0.5.1 parser"
    assert text.startswith("HloModule")


def test_loco_kernel_lowering_shapes():
    with tempfile.TemporaryDirectory() as d:
        aot.build_loco_kernel(256, d)
        path = os.path.join(d, "loco_step_256.hlo.txt")
        text = open(path).read()
        assert "HloModule" in text
        assert "s8[256]" in text  # int8 outputs present


@pytest.mark.parametrize("name", ["tiny"])
def test_full_model_build_smoke(name):
    with tempfile.TemporaryDirectory() as d:
        aot.build_model(CONFIGS[name], d)
        for kind in ("train", "eval"):
            p = os.path.join(d, f"model_{name}_{kind}.hlo.txt")
            assert os.path.getsize(p) > 1000
        assert os.path.exists(os.path.join(d, f"model_{name}.manifest"))

"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/scales/betas for the LoCo kernel and
shapes for the attention kernel; assert_allclose against ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import causal_attention
from compile.kernels.loco_quant import loco_step, vmem_bytes

jax.config.update("jax_platform_name", "cpu")


def _rand(key, n, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), (n,), jnp.float32)


# ---------------------------------------------------------------- loco_step

@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([128, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
    log2_s=st.integers(4, 19),
    se_mult=st.sampled_from([4.0, 6.0]),
    beta=st.sampled_from([0.0, 0.05, 0.5, 1.0]),
    reset=st.booleans(),
    gscale=st.sampled_from([1e-4, 1e-2, 1.0]),
)
def test_loco_step_matches_ref(n_blocks, block, seed, log2_s, se_mult,
                               beta, reset, gscale):
    n = n_blocks * block
    key = jax.random.PRNGKey(seed)
    kg, ke = jax.random.split(key)
    g = gscale * jax.random.normal(kg, (n,), jnp.float32)
    e_q = jax.random.randint(ke, (n,), -128, 128, jnp.int8)
    s = float(2 ** log2_s)
    s_e = se_mult * s

    q_ref, e_ref_new = ref.loco_step_ref(g, e_q, s, s_e, beta, reset)
    q_pl, e_pl = loco_step(g, e_q, s, s_e, beta, int(reset), block=block)

    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_pl))
    np.testing.assert_array_equal(np.asarray(e_ref_new), np.asarray(e_pl))


def test_loco_step_q4_range():
    g = _rand(0, 4096, scale=100.0)
    e = jnp.zeros(4096, jnp.int8)
    q, _ = loco_step(g, e, 2.0**19, 4 * 2.0**19, 0.05, 0, block=1024)
    assert int(q.min()) >= -8 and int(q.max()) <= 7


def test_loco_step_reset_zeroes_error():
    g = _rand(1, 2048)
    e = jnp.full(2048, 55, jnp.int8)
    _, e_new = loco_step(g, e, 16.0, 64.0, 0.1, 1, block=1024)
    assert int(jnp.abs(e_new).max()) == 0


def test_loco_step_zero_input_zero_error():
    g = jnp.zeros(1024, jnp.float32)
    e = jnp.zeros(1024, jnp.int8)
    q, e_new = loco_step(g, e, 16.0, 64.0, 0.1, 0, block=1024)
    assert int(jnp.abs(q).max()) == 0
    assert int(jnp.abs(e_new).max()) == 0


def test_loco_error_feedback_reduces_bias():
    """Accumulated dequantized gradient should track the true sum much
    better WITH error feedback than without (the paper's core claim)."""
    steps, n = 64, 512
    s = 8.0  # coarse on purpose
    s_e = 4 * s
    # beta=1.0 recovers vanilla error feedback; smaller betas trade bias
    # for variance and need error increments above the int8 store's
    # resolution (1/s_e) to accumulate — covered by the rust-side tests
    # with fp32 error stores.
    beta = 1.0
    g_sum = np.zeros(n, np.float64)
    d_sum_ef = np.zeros(n, np.float64)
    d_sum_plain = np.zeros(n, np.float64)
    e = jnp.zeros(n, jnp.int8)
    for k in range(steps):
        g = _rand(1000 + k, n, scale=0.05)
        g_sum += np.asarray(g, np.float64)
        q, e = loco_step(g, e, s, s_e, beta, 0, block=n)
        d_sum_ef += np.asarray(q, np.float64) / s
        q_plain = np.clip(np.round(np.asarray(g) * s), -8, 7)
        d_sum_plain += q_plain / s
    err_ef = np.linalg.norm(d_sum_ef - g_sum)
    err_plain = np.linalg.norm(d_sum_plain - g_sum)
    assert err_ef < 0.5 * err_plain


def test_vmem_budget():
    # DESIGN §Perf: default tile must fit in a 16 MiB VMEM with double buffer
    assert 2 * vmem_bytes() < 16 * 2**20


# ---------------------------------------------------------------- attention

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([8, 16, 64]),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, t, h, dh, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, t, h, dh), jnp.float32)
    k = jax.random.normal(kk, (b, t, h, dh), jnp.float32)
    v = jax.random.normal(kv, (b, t, h, dh), jnp.float32)
    out = causal_attention(q, k, v)
    want = jnp.stack([ref.attention_ref(q[i], k[i], v[i]) for i in range(b)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_grads_finite():
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    shape = (2, 16, 2, 8)
    q = jax.random.normal(kq, shape, jnp.float32)
    k = jax.random.normal(kk, shape, jnp.float32)
    v = jax.random.normal(kv, shape, jnp.float32)
    loss = lambda q, k, v: jnp.sum(causal_attention(q, k, v) ** 2)
    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g)))


def test_attention_grad_matches_dense_ref_grad():
    """custom_vjp backward vs autodiff through the dense oracle."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    shape = (1, 12, 2, 8)
    q = jax.random.normal(kq, shape, jnp.float32)
    k = jax.random.normal(kk, shape, jnp.float32)
    v = jax.random.normal(kv, shape, jnp.float32)

    def loss_pl(q, k, v):
        return jnp.sum(jnp.sin(causal_attention(q, k, v)))

    def loss_ref(q, k, v):
        out = jnp.stack([ref.attention_ref(q[i], k[i], v[i])
                         for i in range(q.shape[0])])
        return jnp.sum(jnp.sin(out))

    g_pl = jax.grad(loss_pl, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pl, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

"""L2 correctness: model shapes, losses, grads, and MoE routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (CONFIGS, forward_loss, init_params,
                           make_eval_fn, make_train_fn, param_count,
                           param_spec)

jax.config.update("jax_platform_name", "cpu")


def _batch(cfg, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (cfg.batch, cfg.seq), 0, cfg.vocab, jnp.int32)


@pytest.mark.parametrize("name", ["tiny", "moe_tiny"])
def test_loss_is_near_uniform_at_init(name):
    cfg = CONFIGS[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    loss = forward_loss(params, _batch(cfg), cfg)
    # random init => loss close to ln(V)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("name", ["tiny", "moe_tiny"])
def test_train_fn_shapes(name):
    cfg = CONFIGS[name]
    params = init_params(cfg, jax.random.PRNGKey(1))
    out = make_train_fn(cfg)(*params, _batch(cfg))
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_eval_fn_matches_forward():
    cfg = CONFIGS["tiny"]
    params = init_params(cfg, jax.random.PRNGKey(2))
    tok = _batch(cfg, 3)
    (le,) = make_eval_fn(cfg)(*params, tok)
    lf = forward_loss(params, tok, cfg)
    np.testing.assert_allclose(float(le), float(lf), rtol=1e-6)


def test_param_spec_counts():
    for name, cfg in CONFIGS.items():
        spec = param_spec(cfg)
        assert len({n for n, _ in spec}) == len(spec), f"dup names in {name}"
        assert param_count(cfg) == sum(
            int(np.prod(s)) for _, s in spec)


def test_param_count_magnitudes():
    assert param_count(CONFIGS["tiny"]) < 500_000
    assert 15e6 < param_count(CONFIGS["base20m"]) < 40e6
    assert 80e6 < param_count(CONFIGS["base100m"]) < 130e6


def test_gradient_descent_reduces_loss():
    """A few SGD steps on one batch must reduce the loss (sanity that the
    lowered fwd/bwd graph is a usable training signal)."""
    cfg = CONFIGS["tiny"]
    params = init_params(cfg, jax.random.PRNGKey(4))
    tok = _batch(cfg, 5)
    fn = jax.jit(make_train_fn(cfg))
    first = None
    for _ in range(5):
        out = fn(*params, tok)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert float(loss) < first - 0.05


def test_moe_uses_multiple_experts():
    cfg = CONFIGS["moe_tiny"]
    params = init_params(cfg, jax.random.PRNGKey(6))
    # router weights are at index 8 for layer0 (after emb, pos, 6 attn/ln)
    names = [n for n, _ in param_spec(cfg)]
    ridx = names.index("layer0.router")
    router = params[ridx]
    x = jax.random.normal(jax.random.PRNGKey(7), (64, cfg.d_model))
    logits = x @ router
    top = jnp.argmax(logits, axis=-1)
    assert len(set(np.asarray(top).tolist())) >= 2
